//! Fault-injection harness for session durability tests.
//!
//! [`MockChain`] is a [`ChainClient`] whose servers carry *stateful*
//! per-session accumulators standing in for KV caches: every prefill and
//! decode step folds its inputs (and per-row cache lengths) into the
//! accumulator, and every output depends on the accumulator's value at
//! that instant. A recovery that replays the wrong history, or a
//! migration that moves the wrong bytes, therefore produces visibly
//! different outputs — "the tokens still match" becomes a real assertion
//! instead of a vacuous one.
//!
//! [`FaultyClient`] wraps any [`FaultInjectable`] transport and fires a
//! scripted [`FaultPlan`] at exact decode-step call ordinals, so tests
//! drive kills and live drains at deterministic points mid-generation.

use crate::coordinator::routing::ServerView;
use crate::coordinator::session::ChainClient;
use crate::dht::NodeId;
use crate::error::{Error, Result};
use crate::model::tensor::Tensor;
use crate::trace::{fresh_span_id, StepBreakdown, TraceContext};
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// The "dial address" a mock server advertises in `moved:` redirects.
fn mock_addr(id: NodeId) -> String {
    format!("mock:{}", id.short())
}

/// Per-session mock "KV state": a running accumulator every request
/// folds into. Replaying identical inputs rebuilds an identical value;
/// migrating copies it verbatim — exactly the two durability paths the
/// real pool supports.
#[derive(Debug, Clone, Default, PartialEq)]
struct MockKv {
    /// Every fold this session absorbed, in order: the declared per-row
    /// cache lens (empty for prefills) and the folded contribution.
    /// Keeping the entries — not just the running value — is what makes
    /// the accumulator *rollbackable*: discarding a speculative suffix
    /// truncates entries and replays the fold, exactly as the real pool
    /// frees suffix pages and later rewrites them.
    entries: Vec<(Vec<usize>, f64)>,
    acc: f64,
    prefills: usize,
    steps: usize,
}

impl MockKv {
    fn fold_value(h: &Tensor, lens: &[usize]) -> f64 {
        // order-stable f64 arithmetic: two runs folding the same inputs
        // in the same order land on bitwise-equal accumulators
        let mut s = 0.0f64;
        for &v in h.as_f32() {
            s += v as f64;
        }
        for &l in lens {
            s += l as f64 * 0.001;
        }
        s
    }

    fn recompute(&mut self) {
        self.acc = self.entries.iter().fold(0.0, |a, (_, s)| a * 0.9990234375 + s);
    }

    fn fold(&mut self, h: &Tensor, lens: &[usize]) {
        let s = Self::fold_value(h, lens);
        self.entries.push((lens.to_vec(), s));
        self.acc = self.acc * 0.9990234375 + s; // exact in binary fp
    }

    /// The server-side implicit-rollback rule (wire v8): a step that
    /// declares cache lens at or below an already-folded step's lens
    /// discards that speculative suffix first. Prefill entries (empty
    /// lens) never roll back. Plain sequential traffic declares strictly
    /// increasing lens, so this is a no-op for it.
    fn rollback_to(&mut self, lens: &[usize]) {
        let mut changed = false;
        while let Some((el, _)) = self.entries.last() {
            if !el.is_empty()
                && el.len() == lens.len()
                && el.iter().zip(lens).all(|(a, b)| a >= b)
            {
                self.entries.pop();
                self.steps = self.steps.saturating_sub(1);
                changed = true;
            } else {
                break;
            }
        }
        if changed {
            self.recompute();
        }
    }
}

struct MockServer {
    id: NodeId,
    start: usize,
    end: usize,
    alive: bool,
    /// Per-session `moved:` redirects left behind by migrations (the
    /// real server's moved map is per-session too — a drained server
    /// can still accept and serve NEW sessions).
    moved: HashMap<u64, String>,
    sessions: HashMap<u64, MockKv>,
    rows_closed: Vec<(u64, usize)>,
}

/// A deterministic in-memory swarm with stateful per-session compute.
pub struct MockChain {
    state: Mutex<Vec<MockServer>>,
    /// Artificial per-step compute time, so trace-coverage assertions
    /// measure something larger than clock noise. Zero by default.
    step_work: Mutex<Duration>,
}

impl MockChain {
    /// `spans`: (name, start, end) per server.
    pub fn new(spans: &[(&str, usize, usize)]) -> Self {
        MockChain {
            state: Mutex::new(
                spans
                    .iter()
                    .map(|(n, s, e)| MockServer {
                        id: NodeId::from_name(n),
                        start: *s,
                        end: *e,
                        alive: true,
                        moved: HashMap::new(),
                        sessions: HashMap::new(),
                        rows_closed: Vec::new(),
                    })
                    .collect(),
            ),
            step_work: Mutex::new(Duration::ZERO),
        }
    }

    /// Make every step (traced or not) burn `d` of wall clock inside the
    /// "executor" stage. Applied identically on both paths so traced and
    /// untraced runs stay bitwise-comparable.
    pub fn set_step_work(&self, d: Duration) {
        *self.step_work.lock().unwrap() = d;
    }

    pub fn kill(&self, id: NodeId) {
        let mut st = self.state.lock().unwrap();
        if let Some(s) = st.iter_mut().find(|s| s.id == id) {
            s.alive = false;
        }
    }

    /// Live-migrate every session on `donor` to `target`: the per-session
    /// state is copied VERBATIM (the mock twin of a KV snapshot push) and
    /// the donor leaves a `moved:` redirect behind — the same observable
    /// protocol [`crate::server::ServerNode`] speaks on the wire.
    pub fn drain(&self, donor: NodeId, target: NodeId) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        let addr = mock_addr(target);
        let di = st
            .iter()
            .position(|s| s.id == donor)
            .ok_or_else(|| Error::NotFound("donor".into()))?;
        let ti = st
            .iter()
            .position(|s| s.id == target)
            .ok_or_else(|| Error::NotFound("target".into()))?;
        let moved: Vec<(u64, MockKv)> = st[di].sessions.drain().collect();
        for (sid, kv) in moved {
            st[di].moved.insert(sid, addr.clone());
            // receiving a migration clears any stale redirect on the
            // target (the real migrate_in_done's rule): it now OWNS the
            // session and must serve, not bounce
            st[ti].moved.remove(&sid);
            st[ti].sessions.insert(sid, kv);
        }
        Ok(())
    }

    /// Relocate `server` to a new block span — the mock twin of a live
    /// rebalance move ([`crate::rebalance::execute_move`]): the span
    /// changes immediately (discovery reflects it on the next refresh),
    /// and live sessions are handed VERBATIM to the first alive peer
    /// still covering the old span, with per-session `moved:` redirects
    /// left behind so in-flight clients bounce instead of erroring.
    /// Returns `(migrated, stranded)`.
    pub fn move_span(
        &self,
        server: NodeId,
        new_start: usize,
        new_end: usize,
    ) -> Result<(usize, usize)> {
        let mut st = self.state.lock().unwrap();
        let si = st
            .iter()
            .position(|s| s.id == server)
            .ok_or_else(|| Error::NotFound("server".into()))?;
        let (old_start, old_end) = (st[si].start, st[si].end);
        st[si].start = new_start;
        st[si].end = new_end;
        if (new_start <= old_start && new_end >= old_end) || st[si].sessions.is_empty() {
            // the new span still covers every session's blocks (or there
            // is nothing to move): sessions stay put
            return Ok((0, 0));
        }
        let Some(ti) = st
            .iter()
            .position(|s| s.alive && s.id != server && s.start <= old_start && s.end >= old_end)
        else {
            // nobody covers the old span: sessions stay live on the
            // mover — stranded, exactly what the real drain reports
            return Ok((0, st[si].sessions.len()));
        };
        let addr = mock_addr(st[ti].id);
        let moved: Vec<(u64, MockKv)> = st[si].sessions.drain().collect();
        let n = moved.len();
        for (sid, kv) in moved {
            st[si].moved.insert(sid, addr.clone());
            st[ti].moved.remove(&sid);
            st[ti].sessions.insert(sid, kv);
        }
        Ok((n, 0))
    }

    /// Rows released early on `server` (assertions on per-row exit).
    pub fn rows_closed(&self, server: NodeId) -> Vec<(u64, usize)> {
        let st = self.state.lock().unwrap();
        st.iter()
            .find(|s| s.id == server)
            .map(|s| s.rows_closed.clone())
            .unwrap_or_default()
    }

    /// How many sessions `server` currently holds state for.
    pub fn session_count(&self, server: NodeId) -> usize {
        let st = self.state.lock().unwrap();
        st.iter().find(|s| s.id == server).map(|s| s.sessions.len()).unwrap_or(0)
    }

    fn apply(h: &Tensor, span: usize, acc: f64) -> Tensor {
        let mut out = h.clone();
        // every output element depends on the accumulator: divergent
        // state becomes divergent output immediately
        let tag = ((acc.rem_euclid(1024.0)) as f32) * 1e-4;
        for v in out.as_f32_mut() {
            *v += span as f32 + tag;
        }
        out
    }

    fn run(
        &self,
        server: NodeId,
        session: u64,
        lens: &[usize],
        h: &Tensor,
        is_prefill: bool,
    ) -> Result<Tensor> {
        self.run_timed(server, session, lens, h, is_prefill).map(|(t, _)| t)
    }

    /// The compute path, instrumented with the same stage clocks the real
    /// server uses: queue (lock wait), gather (session-state fetch + fold),
    /// exec (apply + artificial work), commit (counter updates). `fuse` is
    /// always zero — the mock has no fusion window.
    fn run_timed(
        &self,
        server: NodeId,
        session: u64,
        lens: &[usize],
        h: &Tensor,
        is_prefill: bool,
    ) -> Result<(Tensor, StepBreakdown)> {
        let us = |d: Duration| d.as_micros().min(u32::MAX as u128) as u32;
        let t0 = Instant::now();
        let work = *self.step_work.lock().unwrap();
        let mut st = self.state.lock().unwrap();
        let queue_us = us(t0.elapsed());
        let t_gather = Instant::now();
        let srv = st
            .iter_mut()
            .find(|s| s.id == server)
            .ok_or_else(|| Error::NotFound(format!("server {}", server.short())))?;
        if !srv.alive {
            return Err(Error::ChainBroken(format!("server {} is down", server.short())));
        }
        if let Some(addr) = srv.moved.get(&session) {
            return Err(Error::Moved(addr.clone()));
        }
        let span = srv.end - srv.start;
        let kv = srv
            .sessions
            .get_mut(&session)
            .ok_or_else(|| Error::NotFound(format!("session {session}")))?;
        if !is_prefill {
            kv.rollback_to(lens);
        }
        kv.fold(h, lens);
        let gather_us = us(t_gather.elapsed());
        let t_exec = Instant::now();
        if !work.is_zero() {
            std::thread::sleep(work);
        }
        let acc = kv.acc;
        let out = Self::apply(h, span, acc);
        let exec_us = us(t_exec.elapsed());
        let t_commit = Instant::now();
        if is_prefill {
            kv.prefills += 1;
        } else {
            kv.steps += 1;
        }
        let commit_us = us(t_commit.elapsed());
        let bd = StepBreakdown {
            span_id: fresh_span_id(),
            queue_us,
            fuse_us: 0,
            gather_us,
            exec_us,
            commit_us,
            total_us: us(t0.elapsed()),
        };
        Ok((out, bd))
    }
}

impl ChainClient for MockChain {
    fn discover(&self) -> Vec<ServerView> {
        let st = self.state.lock().unwrap();
        st.iter()
            .filter(|s| s.alive)
            .map(|s| ServerView {
                id: s.id,
                start: s.start,
                end: s.end,
                latency_s: 0.001,
                bandwidth_bps: 1e9,
                span_compute_s: 0.01 * (s.end - s.start) as f64,
                queue_depth: 0,
                free_ratio: 1.0,
                prefix_fps: vec![],
                p50_step_us: 0,
                measured_step_s: None,
                measured_age_s: 0.0,
            })
            .collect()
    }

    fn open_session(
        &self,
        server: NodeId,
        session: u64,
        _batch: usize,
        _prefix_len: usize,
        _max_new: usize,
    ) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        let srv = st
            .iter_mut()
            .find(|s| s.id == server)
            .ok_or_else(|| Error::NotFound(format!("server {}", server.short())))?;
        if !srv.alive {
            return Err(Error::ChainBroken(format!("server {} is down", server.short())));
        }
        srv.moved.remove(&session); // id reuse starts a new session
        srv.sessions.insert(session, MockKv::default());
        Ok(())
    }

    fn prefill(&self, server: NodeId, session: u64, hidden: &Tensor) -> Result<Tensor> {
        self.run(server, session, &[], hidden, true)
    }

    fn step(
        &self,
        server: NodeId,
        session: u64,
        cache_len: usize,
        hidden: &Tensor,
    ) -> Result<Tensor> {
        self.run(server, session, &[cache_len], hidden, false)
    }

    fn step_ragged(
        &self,
        server: NodeId,
        session: u64,
        row_lens: &[usize],
        hidden: &Tensor,
    ) -> Result<Tensor> {
        self.run(server, session, row_lens, hidden, false)
    }

    fn step_traced(
        &self,
        server: NodeId,
        session: u64,
        row_lens: &[usize],
        hidden: &Tensor,
        _ctx: &TraceContext,
    ) -> Result<(Tensor, Option<StepBreakdown>)> {
        self.run_timed(server, session, row_lens, hidden, false).map(|(t, bd)| (t, Some(bd)))
    }

    fn close_session(&self, server: NodeId, session: u64) {
        let mut st = self.state.lock().unwrap();
        if let Some(srv) = st.iter_mut().find(|s| s.id == server) {
            srv.sessions.remove(&session);
        }
    }

    fn close_row(&self, server: NodeId, session: u64, row: usize) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        let srv = st
            .iter_mut()
            .find(|s| s.id == server)
            .ok_or_else(|| Error::NotFound(format!("server {}", server.short())))?;
        srv.rows_closed.push((session, row));
        Ok(())
    }

    fn resolve_moved(&self, addr: &str) -> Option<NodeId> {
        let st = self.state.lock().unwrap();
        st.iter().find(|s| s.alive && mock_addr(s.id) == addr).map(|s| s.id)
    }

    fn forward(&self, server: NodeId, hidden: &Tensor) -> Result<Tensor> {
        let st = self.state.lock().unwrap();
        let srv = st
            .iter()
            .find(|s| s.id == server)
            .ok_or_else(|| Error::NotFound(format!("server {}", server.short())))?;
        if !srv.alive {
            return Err(Error::ChainBroken("down".into()));
        }
        Ok(Self::apply(hidden, srv.end - srv.start, 0.0))
    }

    fn backward(&self, _server: NodeId, _hidden: &Tensor, grad: &Tensor) -> Result<Tensor> {
        Ok(grad.clone())
    }
}

/// A transport that supports injected faults — implemented by the mock
/// swarm here and by [`crate::server::LocalCluster`] (real servers, real
/// KV pools), so the same scripted scenarios run at both fidelities.
pub trait FaultInjectable: ChainClient {
    /// Hard-kill a server (crash: state lost, requests fail).
    fn inject_kill(&self, server: NodeId);
    /// Gracefully drain `donor` onto `target` (live migration: state
    /// moves, requests redirect).
    fn inject_drain(&self, donor: NodeId, target: NodeId) -> Result<()>;
}

impl FaultInjectable for MockChain {
    fn inject_kill(&self, server: NodeId) {
        self.kill(server);
    }
    fn inject_drain(&self, donor: NodeId, target: NodeId) -> Result<()> {
        self.drain(donor, target)
    }
}

impl FaultInjectable for crate::server::LocalCluster {
    fn inject_kill(&self, server: NodeId) {
        self.kill(server);
    }
    fn inject_drain(&self, donor: NodeId, target: NodeId) -> Result<()> {
        let node = self
            .node(donor)
            .ok_or_else(|| Error::NotFound(format!("server {}", donor.short())))?;
        node.set_draining(true);
        for session in node.live_sessions() {
            self.migrate_session(donor, target, session)?;
        }
        Ok(())
    }
}

/// What to do when a [`FaultPlan`] fires.
#[derive(Debug, Clone)]
pub enum FaultAction {
    Kill(NodeId),
    Drain { donor: NodeId, target: NodeId },
}

/// Fire `action` immediately BEFORE the `at_step_call`-th decode-step
/// request (0-based, counted across all hops) reaches the transport.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub at_step_call: usize,
    pub action: FaultAction,
}

/// Wraps a [`FaultInjectable`] transport and fires scripted faults at
/// exact decode-step ordinals — deterministic churn for durability
/// tests. All non-step traffic passes through untouched.
pub struct FaultyClient<C: FaultInjectable> {
    inner: C,
    plans: Mutex<Vec<FaultPlan>>,
    step_calls: Mutex<usize>,
}

impl<C: FaultInjectable> FaultyClient<C> {
    pub fn new(inner: C, plans: Vec<FaultPlan>) -> Self {
        FaultyClient { inner, plans: Mutex::new(plans), step_calls: Mutex::new(0) }
    }

    pub fn inner(&self) -> &C {
        &self.inner
    }

    /// Replace the fault script (e.g. after routing reveals which
    /// replica actually serves a span).
    pub fn script(&self, plans: Vec<FaultPlan>) {
        *self.plans.lock().unwrap() = plans;
    }

    /// Faults that have not fired yet (0 = the full script ran).
    pub fn pending_faults(&self) -> usize {
        self.plans.lock().unwrap().len()
    }

    fn before_step(&self) {
        let n = {
            let mut c = self.step_calls.lock().unwrap();
            let n = *c;
            *c += 1;
            n
        };
        let due: Vec<FaultPlan> = {
            let mut plans = self.plans.lock().unwrap();
            let (fire, keep): (Vec<_>, Vec<_>) =
                plans.drain(..).partition(|p| p.at_step_call == n);
            *plans = keep;
            fire
        };
        for plan in due {
            match plan.action {
                FaultAction::Kill(id) => self.inner.inject_kill(id),
                FaultAction::Drain { donor, target } => {
                    // a failed drain leaves the session running on the
                    // donor — the test's assertions decide if that's fatal
                    let _ = self.inner.inject_drain(donor, target);
                }
            }
        }
    }
}

impl<C: FaultInjectable> ChainClient for FaultyClient<C> {
    fn discover(&self) -> Vec<ServerView> {
        self.inner.discover()
    }
    fn open_session(
        &self,
        server: NodeId,
        session: u64,
        batch: usize,
        prefix_len: usize,
        max_new: usize,
    ) -> Result<()> {
        self.inner.open_session(server, session, batch, prefix_len, max_new)
    }
    #[allow(clippy::too_many_arguments)]
    fn open_session_prefixed(
        &self,
        server: NodeId,
        session: u64,
        batch: usize,
        prefix_len: usize,
        max_new: usize,
        prefix_tokens: &[i32],
        prefill_width: usize,
    ) -> Result<()> {
        self.inner.open_session_prefixed(
            server,
            session,
            batch,
            prefix_len,
            max_new,
            prefix_tokens,
            prefill_width,
        )
    }
    fn prefill(&self, server: NodeId, session: u64, hidden: &Tensor) -> Result<Tensor> {
        self.inner.prefill(server, session, hidden)
    }
    fn step(
        &self,
        server: NodeId,
        session: u64,
        cache_len: usize,
        hidden: &Tensor,
    ) -> Result<Tensor> {
        self.before_step();
        self.inner.step(server, session, cache_len, hidden)
    }
    fn step_ragged(
        &self,
        server: NodeId,
        session: u64,
        row_lens: &[usize],
        hidden: &Tensor,
    ) -> Result<Tensor> {
        self.before_step();
        self.inner.step_ragged(server, session, row_lens, hidden)
    }
    fn step_traced(
        &self,
        server: NodeId,
        session: u64,
        row_lens: &[usize],
        hidden: &Tensor,
        ctx: &TraceContext,
    ) -> Result<(Tensor, Option<StepBreakdown>)> {
        // a traced step consumes the same fault ordinal an untraced one
        // would — scripted kills fire identically with tracing on
        self.before_step();
        self.inner.step_traced(server, session, row_lens, hidden, ctx)
    }
    fn propose_verify(
        &self,
        server: NodeId,
        session: u64,
        base_lens: &[usize],
        hidden: &Tensor,
    ) -> Result<Tensor> {
        // one verify round = one wire call = one fault ordinal, so
        // scripts can kill a server exactly mid-round
        self.before_step();
        self.inner.propose_verify(server, session, base_lens, hidden)
    }
    fn close_session(&self, server: NodeId, session: u64) {
        self.inner.close_session(server, session)
    }
    fn close_row(&self, server: NodeId, session: u64, row: usize) -> Result<()> {
        self.inner.close_row(server, session, row)
    }
    fn resolve_moved(&self, addr: &str) -> Option<NodeId> {
        self.inner.resolve_moved(addr)
    }
    fn forward(&self, server: NodeId, hidden: &Tensor) -> Result<Tensor> {
        self.inner.forward(server, hidden)
    }
    fn backward(&self, server: NodeId, hidden: &Tensor, grad: &Tensor) -> Result<Tensor> {
        self.inner.backward(server, hidden, grad)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::routing::RouteQuery;
    use crate::coordinator::session::{InferenceSession, PromptShape, SessionConfig};

    fn cfg(n_blocks: usize) -> SessionConfig {
        SessionConfig {
            n_blocks,
            max_new: 16,
            route: RouteQuery { n_blocks, msg_bytes: 64, ..Default::default() },
            max_recoveries: 4,
            prefix_tokens: vec![],
        }
    }

    fn shape() -> PromptShape {
        PromptShape { batch: 1, prefix_len: 2, prefill_width: 4 }
    }

    fn run_tokens<C: ChainClient>(client: C, sid: u64, n: usize) -> Vec<Vec<f32>> {
        let mut s = InferenceSession::open(client, cfg(8), shape(), sid).unwrap();
        s.prefill(Tensor::from_f32(&[1, 4, 4], &[0.5; 16])).unwrap();
        let mut outs = Vec::new();
        for i in 0..n {
            let h = Tensor::from_f32(&[1, 1, 4], &[i as f32 * 0.25; 4]);
            outs.push(s.step(h).unwrap().as_f32().to_vec());
        }
        s.close();
        outs
    }

    /// The harness's reason to exist: outputs must DEPEND on accumulated
    /// state, so a run with different history visibly diverges.
    #[test]
    fn outputs_depend_on_session_history() {
        let chain = MockChain::new(&[("a", 0, 4), ("b", 4, 8)]);
        let mut s = InferenceSession::open(&chain, cfg(8), shape(), 1).unwrap();
        s.prefill(Tensor::from_f32(&[1, 4, 4], &[0.5; 16])).unwrap();
        let h = Tensor::from_f32(&[1, 1, 4], &[1.0; 4]);
        let first = s.step(h.clone()).unwrap();
        let second = s.step(h).unwrap();
        assert_ne!(
            first.as_f32(),
            second.as_f32(),
            "identical inputs must produce different outputs as state accrues"
        );
        s.close();
    }

    /// A mid-generation kill recovers by replay and the output sequence
    /// is bitwise-identical to the undisturbed run.
    #[test]
    fn kill_recovery_is_bitwise_identical() {
        let baseline = run_tokens(
            &MockChain::new(&[("a", 0, 4), ("b", 4, 8), ("b2", 4, 8)]),
            1,
            6,
        );
        let chain = MockChain::new(&[("a", 0, 4), ("b", 4, 8), ("b2", 4, 8)]);
        let faulty = FaultyClient::new(chain, vec![]);
        // killing BOTH replicas of the second span would strand the
        // chain, so only script the one the route actually picked
        let mut s = InferenceSession::open(&faulty, cfg(8), shape(), 1).unwrap();
        let hop1 = s.chain()[1].server;
        faulty.script(vec![FaultPlan { at_step_call: 6, action: FaultAction::Kill(hop1) }]);
        s.prefill(Tensor::from_f32(&[1, 4, 4], &[0.5; 16])).unwrap();
        let mut outs = Vec::new();
        for i in 0..6 {
            let h = Tensor::from_f32(&[1, 1, 4], &[i as f32 * 0.25; 4]);
            outs.push(s.step(h).unwrap().as_f32().to_vec());
        }
        assert_eq!(s.recoveries(), 1, "the scripted kill must have fired");
        assert_eq!(outs, baseline, "recovered run diverged from baseline");
        assert_eq!(faulty.pending_faults(), 0);
        s.close();
    }

    /// A scripted live drain redirects the client and the sequence stays
    /// bitwise-identical WITHOUT any replay (state moved, not rebuilt).
    #[test]
    fn drain_migration_is_bitwise_identical_without_replay() {
        let baseline = run_tokens(&MockChain::new(&[("a", 0, 4), ("b", 4, 8)]), 2, 6);
        let chain = MockChain::new(&[("a", 0, 4), ("b", 4, 8), ("spare", 4, 8)]);
        let faulty = FaultyClient::new(chain, vec![]);
        let mut s = InferenceSession::open(&faulty, cfg(8), shape(), 2).unwrap();
        // route may have picked either replica of the 4..8 span; drain
        // whichever is live in the chain onto the other
        let hop1 = s.chain()[1].server;
        let target = if hop1 == NodeId::from_name("b") {
            NodeId::from_name("spare")
        } else {
            NodeId::from_name("b")
        };
        faulty.script(vec![FaultPlan {
            at_step_call: 6,
            action: FaultAction::Drain { donor: hop1, target },
        }]);
        s.prefill(Tensor::from_f32(&[1, 4, 4], &[0.5; 16])).unwrap();
        let mut outs = Vec::new();
        for i in 0..6 {
            let h = Tensor::from_f32(&[1, 1, 4], &[i as f32 * 0.25; 4]);
            outs.push(s.step(h).unwrap().as_f32().to_vec());
        }
        assert_eq!(outs, baseline, "migrated run diverged from baseline");
        assert_eq!(s.recoveries(), 0, "migration must not be a replay recovery");
        assert_eq!(s.chain()[1].server, target);
        let inner = faulty.inner();
        assert_eq!(inner.session_count(hop1), 0, "donor dropped its replica");
        assert_eq!(inner.session_count(target), 1, "target holds the session");
        s.close();
    }

    /// Tracing must be a pure observer: a traced run produces the exact
    /// same token outputs as an untraced one, and every hop reports a
    /// populated breakdown.
    #[test]
    fn traced_run_is_bitwise_identical_to_untraced() {
        use crate::trace::{fresh_span_id, fresh_trace_id, TraceContext};
        let baseline = run_tokens(&MockChain::new(&[("a", 0, 4), ("b", 4, 8)]), 7, 4);
        let chain = MockChain::new(&[("a", 0, 4), ("b", 4, 8)]);
        let ctx = TraceContext { trace_id: fresh_trace_id(), parent_span: fresh_span_id() };
        let mut s = InferenceSession::open(&chain, cfg(8), shape(), 7).unwrap();
        s.prefill(Tensor::from_f32(&[1, 4, 4], &[0.5; 16])).unwrap();
        let mut outs = Vec::new();
        for i in 0..4 {
            let h = Tensor::from_f32(&[1, 1, 4], &[i as f32 * 0.25; 4]);
            let (out, hops) = s.step_traced(h, &ctx).unwrap();
            assert_eq!(hops.len(), 2, "one HopTrace per chain hop");
            for hop in &hops {
                let bd = hop.breakdown.expect("MockChain returns a native breakdown");
                assert!(bd.stage_sum_us() <= bd.total_us as u64);
            }
            outs.push(out.as_f32().to_vec());
        }
        assert_eq!(outs, baseline, "tracing perturbed the computed outputs");
        s.close();
    }
}
