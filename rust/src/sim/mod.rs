//! Discrete-event swarm simulator: regenerates Table 3 (§3.3).
//!
//! The paper measures BLOOM-176B over hardware we do not have; per
//! DESIGN.md §Substitutions this simulator runs the *same coordinator
//! logic* (block assignment via [`crate::coordinator::balancer`], chain
//! selection via [`crate::coordinator::routing`]) with a calibrated
//! analytic compute model ([`crate::config::profiles`]) and a
//! deterministic network model. Multi-client contention emerges from
//! per-server busy intervals (FIFO), not from a closed-form formula.
//!
//! What it reproduces:
//! - single-batch inference steps/s (sequence length via `prefix_len` +
//!   `n_steps`),
//! - parallel forward tokens/s (GPipe-style microbatch pipelining),
//! - the ≈20% per-client slowdown with 8 concurrent clients,
//! - churn experiments (servers leaving; rebalancing closing gaps).
//!
//! The [`dht`] submodule simulates the *discovery* plane the same way:
//! a metered Kademlia swarm with realistic sparse routing tables, used
//! by `benches/dht_lookup.rs` to track lookup hops and churn
//! convergence on the perf trajectory.

pub mod dht;
pub mod faults;

use crate::config::profiles::{NetworkProfile, ServerSpec, SwarmProfile};
use crate::config::Rng;
use crate::coordinator::balancer::{self, BlockCoverage};
use crate::coordinator::routing::{self, ChainHop, RouteQuery, ServerView};
use crate::dht::NodeId;
use crate::draft::MAX_SPEC_K;
use crate::quant;

/// A server in the simulated swarm.
#[derive(Debug, Clone)]
pub struct SimServer {
    pub id: NodeId,
    pub spec: ServerSpec,
    pub span: std::ops::Range<usize>,
    /// FIFO availability: next instant this server is free. Servers in
    /// the same `gpu_group` SHARE this interval (the paper's 12 virtual
    /// servers are partitions of 3 physical A100s — compute serializes
    /// at the physical GPU).
    pub busy_until: f64,
    /// Rows in the decode batch currently in flight (continuous-batching
    /// mode only; resets when the server goes idle).
    pub batch_width_now: usize,
    /// Depth class (cache length) of the in-flight batch — what the
    /// pre-ragged scheduler gated joins on (`None` = prefill/forward
    /// pass, never depth-gated).
    pub batch_class: Option<u64>,
    /// Physical-GPU group; virtual servers on one card share compute.
    pub gpu_group: usize,
    pub alive: bool,
}

impl SimServer {
    fn net<'a>(&'a self, default: &'a NetworkProfile) -> &'a NetworkProfile {
        self.spec.net.as_ref().unwrap_or(default)
    }
}

/// The simulated swarm.
pub struct SwarmSim {
    pub profile: SwarmProfile,
    pub servers: Vec<SimServer>,
    /// Model server-side continuous batching: a decode request arriving
    /// while the server is mid-batch *joins* that batch at marginal cost
    /// (the weight stream is already paid) instead of queueing for a
    /// full serialized pass. Mirrors the real server's
    /// [`crate::server::StepScheduler`].
    pub continuous_batching: bool,
    /// Max rows fused per simulated decode batch.
    pub max_batch_width: usize,
    /// Model the PRE-ragged scheduler: a decode step may only join an
    /// in-flight batch whose rows sit at the SAME cache depth (the old
    /// same-`cache_len` fusion gate). False (the default) models the
    /// ragged scheduler: any distinct session joins regardless of depth.
    /// Only meaningful with [`Self::continuous_batching`] on.
    pub uniform_depth_gate: bool,
    /// Requests that joined an in-flight batch (diagnostics).
    pub batched_joins: usize,
    /// Decode step-hops that joined an in-flight batch (the numerator of
    /// [`Self::decode_occupancy`]).
    pub decode_joins: usize,
    /// Total decode step-hops offered to batched servers.
    pub decode_step_hops: usize,
    /// Model server-side shared-prefix caching: the first prefill of a
    /// prompt template on a server pays the full prefix compute and
    /// registers it; every later prefill of the same template on that
    /// server runs at [`PREFIX_HIT_COST`] of it (KV pages attached, no
    /// recompute). Mirrors the real server's
    /// [`crate::server::prefixcache`].
    pub prefix_cache: bool,
    /// Prefills served from a warm template (diagnostics).
    pub prefix_hits: usize,
    /// Shared bandwidth-token availability per physical GPU group.
    group_busy: std::collections::HashMap<usize, f64>,
    /// Recent claim times per GPU group (processor-sharing window).
    group_claims: std::collections::HashMap<usize, std::collections::VecDeque<(f64, usize)>>,
    rng: Rng,
}

/// Result of an inference workload.
#[derive(Debug, Clone)]
pub struct InferenceReport {
    pub steps: usize,
    pub wall_s: f64,
    pub steps_per_s: f64,
    pub chain_len: usize,
}

/// Result of a parallel-forward workload.
#[derive(Debug, Clone)]
pub struct ForwardReport {
    pub tokens: usize,
    pub wall_s: f64,
    pub tokens_per_s: f64,
}

/// Result of a speculative-decoding workload
/// ([`SwarmSim::run_inference_speculative`]) — the numbers the
/// spec-decode gate tracks in `BENCH_ragged.json`.
#[derive(Debug, Clone)]
pub struct SpecReport {
    /// Committed tokens (always equals the requested `n_steps`).
    pub tokens: usize,
    /// `ProposeVerify` rounds the client issued.
    pub rounds: usize,
    pub wall_s: f64,
    /// Steady-state committed tokens/s (prefill excluded) — compare
    /// against [`InferenceReport::steps_per_s`] from the same swarm.
    pub tokens_per_s: f64,
    /// Mean committed tokens per round; 1.0 when every draft misses.
    pub tokens_per_round: f64,
    /// Measured acceptance: accepted drafts / proposed drafts. Lower
    /// than the per-draft hit probability because a round stops
    /// evaluating at its first miss (the tail drafts count as proposed
    /// but can never be accepted).
    pub accept_rate: f64,
    pub chain_len: usize,
}

/// Fraction of the full prefill compute a warm-template prefill costs
/// (attach shared KV pages + marginal bookkeeping, no block recompute).
pub const PREFIX_HIT_COST: f64 = 0.05;

/// Result of a shared-prefix arrival mix
/// ([`SwarmSim::run_inference_concurrent_mix`]).
#[derive(Debug, Clone)]
pub struct SharedMixReport {
    /// Per-client steady-state decode steps/s.
    pub per_client: Vec<f64>,
    /// Mean seconds from a client's arrival to its first decoded token —
    /// the latency the prefix cache attacks.
    pub mean_ttft_s: f64,
    /// Prefills served from a warm template across all servers.
    pub prefix_hits: usize,
}

/// Result of a mixed-length arrival mix
/// ([`SwarmSim::run_inference_ragged_mix`]) — the numbers
/// `BENCH_ragged.json` tracks on the CI bench trajectory.
#[derive(Debug, Clone)]
pub struct RaggedMixReport {
    /// Per-client steady-state decode steps/s.
    pub per_client: Vec<f64>,
    /// Sum of per-client rates — the swarm's aggregate decode rate.
    pub aggregate_steps_per_s: f64,
    /// Share of decode step-hops that joined an in-flight fused batch
    /// ([`SwarmSim::decode_occupancy`]).
    pub occupancy: f64,
    /// Median seconds from a client's arrival to its first decoded
    /// token.
    pub p50_ttft_s: f64,
    /// Raw decode joins (diagnostics).
    pub decode_joins: usize,
}

/// Result of the adversarial-tenant fairness scenario
/// ([`SwarmSim::run_inference_fair_mix`]): one storming tenant floods
/// the bottleneck with single-row sessions while N well-behaved tenants
/// each run one request. The gated number is the well-behaved cohort's
/// p99 TTFT — bounded under weighted-fair queueing, unbounded under
/// FIFO (the storm's backlog serializes in front of everyone).
#[derive(Debug, Clone)]
pub struct FairMixReport {
    /// p99 time-to-first-token of the well-behaved tenants, seconds.
    pub p99_ttft_s: f64,
    /// Mean TTFT of the well-behaved cohort.
    pub mean_ttft_s: f64,
    /// Decode row-steps the storming tenant got through the bottleneck
    /// (diagnostics: WFQ throttles its share, it does not starve it).
    pub storm_row_steps: usize,
}

/// KV pages one session costs under the paged pool: the full cost of a
/// private session vs the marginal (suffix-only) cost when its
/// `prefix_len`-token prefix is shared — the acceptance metric for the
/// shared-prefix subsystem. Delegates to the *real* pool's accounting
/// ([`crate::server::KvPoolConfig`]) so the sim can never drift from
/// what admission actually charges.
pub fn pages_per_session(
    prefix_len: usize,
    new_tokens: usize,
    page_tokens: usize,
    n_blocks: usize,
    shared: bool,
) -> usize {
    let cfg = crate::server::KvPoolConfig {
        n_heads: 1,
        head_dim: 1,
        page_tokens,
        capacity_pages: 0,
    };
    let total = prefix_len + new_tokens;
    if shared {
        cfg.private_pages(1, n_blocks, prefix_len, total)
    } else {
        cfg.pages_for(1, n_blocks, total)
    }
}

impl SwarmSim {
    /// Build the swarm: servers join one by one, each taking the span
    /// the balancer assigns (the paper's §3.2 join procedure), then
    /// rebalance to a fixed point.
    pub fn build(profile: SwarmProfile, seed: u64) -> Self {
        let rng = Rng::new(seed);
        let n_blocks = profile.n_blocks;
        let mut cov = BlockCoverage::new(n_blocks);
        let mut servers = Vec::with_capacity(profile.servers.len());
        for (i, spec) in profile.servers.iter().enumerate() {
            let capacity = spec.device.capacity_blocks(profile.bytes_per_block).max(1);
            let span = balancer::choose_join_span(&cov, capacity);
            let tput = crate::coordinator::throughput::announced(
                &spec.device,
                spec.net.as_ref().unwrap_or(&profile.default_net),
                span.len(),
                profile.bytes_per_block,
                self_hidden_bytes(&profile),
            );
            cov.add_span(span.clone(), tput);
            // virtual quarters pack 4 per physical card
            let gpu_group = if spec.device.name.starts_with("virtual") { i / 4 } else { i };
            servers.push(SimServer {
                id: NodeId::from_name(&format!("sim-{i}")),
                spec: spec.clone(),
                span,
                busy_until: 0.0,
                batch_width_now: 0,
                batch_class: None,
                gpu_group,
                alive: true,
            });
        }
        let mut sim = SwarmSim {
            profile,
            servers,
            continuous_batching: false,
            max_batch_width: 8,
            uniform_depth_gate: false,
            batched_joins: 0,
            decode_joins: 0,
            decode_step_hops: 0,
            prefix_cache: false,
            prefix_hits: 0,
            group_busy: Default::default(),
            group_claims: Default::default(),
            rng,
        };
        sim.rebalance();
        sim
    }

    /// Re-run the balancer over live servers (paper: periodic check).
    pub fn rebalance(&mut self) -> usize {
        let n_blocks = self.profile.n_blocks;
        let mut spans: Vec<(std::ops::Range<usize>, f64)> = Vec::new();
        let mut idx = Vec::new();
        for (i, s) in self.servers.iter().enumerate() {
            if s.alive {
                spans.push((s.span.clone(), self.announced(s)));
                idx.push(i);
            }
        }
        let moves = balancer::rebalance_to_fixpoint(n_blocks, &mut spans, 0.05, 32);
        for (k, (span, _)) in spans.into_iter().enumerate() {
            self.servers[idx[k]].span = span;
        }
        moves
    }

    fn announced(&self, s: &SimServer) -> f64 {
        crate::coordinator::throughput::announced(
            &s.spec.device,
            s.net(&self.profile.default_net),
            s.span.len().max(1),
            self.profile.bytes_per_block,
            self_hidden_bytes(&self.profile),
        )
    }

    /// Kill a server (churn experiments).
    pub fn kill(&mut self, idx: usize) {
        self.servers[idx].alive = false;
    }

    /// Per-block coverage of live servers.
    pub fn coverage(&self) -> BlockCoverage {
        let mut cov = BlockCoverage::new(self.profile.n_blocks);
        for s in self.servers.iter().filter(|s| s.alive) {
            cov.add_span(s.span.clone(), self.announced(s));
        }
        cov
    }

    /// Client-visible view (what pings + DHT would return).
    pub fn views(&self) -> Vec<ServerView> {
        self.servers
            .iter()
            .filter(|s| s.alive)
            .map(|s| {
                let net = s.net(&self.profile.default_net);
                ServerView {
                    id: s.id,
                    start: s.span.start,
                    end: s.span.end,
                    latency_s: net.one_way_s(),
                    bandwidth_bps: net.bandwidth_bps,
                    span_compute_s: s.spec.device.decode_time(
                        s.span.len(),
                        self.profile.bytes_per_block,
                        1,
                    ),
                    queue_depth: 0,
                    free_ratio: 1.0,
                    prefix_fps: vec![],
                    p50_step_us: 0,
                    measured_step_s: None,
                    measured_age_s: 0.0,
                }
            })
            .collect()
    }

    fn route(&self, batch: usize) -> Option<Vec<ChainHop>> {
        let q = RouteQuery {
            n_blocks: self.profile.n_blocks,
            msg_bytes: step_msg_bytes(&self.profile, batch),
            ..Default::default()
        };
        routing::find_chain(&self.views(), &q).map(|(hops, _)| hops)
    }

    fn server_by_id(&mut self, id: NodeId) -> &mut SimServer {
        self.servers.iter_mut().find(|s| s.id == id).unwrap()
    }

    /// FIFO-claim `compute` seconds for a request arriving at `arrive`.
    /// Two-level contention model:
    /// - the server's own queue fully serializes its requests;
    /// - servers in the same `gpu_group` (virtual partitions of one
    ///   physical card) additionally share the card's memory bandwidth:
    ///   each request holds a group-wide "bandwidth token" for
    ///   GROUP_SHARE of its compute time (decode is memory-bound, but
    ///   MIG-style partitions overlap compute with each other).
    fn occupy(
        &mut self,
        id: NodeId,
        arrive: f64,
        compute: f64,
        client: usize,
        class: Option<u64>,
    ) -> f64 {
        if self.continuous_batching {
            return self.occupy_batched(id, arrive, compute, client, class);
        }
        // A request's memory streaming overlaps other requests' compute
        // (CUDA streams / DMA vs ALU): a server admits the next request
        // after SERVER_OVERLAP of the previous one's duration, instead
        // of fully serializing — without this, convoys of bunched
        // clients compound waits across every hop and the multi-client
        // slowdown triples vs the paper's ~20%.
        const SERVER_OVERLAP: f64 = 1.0;
        // Virtual partitions of one physical card additionally share its
        // memory bandwidth via a group token.
        const GROUP_SHARE: f64 = 0.33;
        // Processor sharing: concurrent requests on one physical card
        // contend for SMs + HBM, inflating each other's service time.
        // This (not queueing) is the dominant term behind the paper's
        // ~20% multi-client slowdown: a closed pipeline of deterministic
        // clients de-synchronizes into low-collision rotation, but SM
        // contention taxes every request that shares a window.
        const PS_ALPHA: f64 = 0.02;
        const PS_WINDOW: f64 = 1.0;
        let (group, own_busy) = {
            let s = self.servers.iter().find(|s| s.id == id).unwrap();
            (s.gpu_group, s.busy_until)
        };
        // processor-sharing inflation from recent co-located claims
        let claims = self.group_claims.entry(group).or_default();
        while claims.front().map(|&(t, _)| t < arrive - PS_WINDOW).unwrap_or(false) {
            claims.pop_front();
        }
        // only OTHER clients' traffic contends (one client is sequential)
        let concurrent = claims.iter().filter(|&&(_, c)| c != client).count() as f64;
        claims.push_back((arrive, client));
        let compute = compute * (1.0 + PS_ALPHA * concurrent);
        let solo = self.servers.iter().filter(|s| s.gpu_group == group).count() == 1;
        let group_busy = if solo {
            0.0
        } else {
            *self.group_busy.entry(group).or_insert(0.0)
        };
        let start = arrive.max(own_busy).max(group_busy);
        let done = start + compute;
        self.server_by_id(id).busy_until = start + compute * SERVER_OVERLAP;
        if !solo {
            self.group_busy.insert(group, start + compute * GROUP_SHARE);
        }
        done
    }

    /// Continuous-batching service model: a request hitting a busy server
    /// rides the in-flight batch for its *marginal* row cost (decode is
    /// memory-bound; the weight stream is shared across fused rows), so
    /// concurrent sessions cost far less than full serialization. A
    /// request hitting an idle server pays the full weight stream and
    /// opens a new batch — subject to the SAME processor-sharing
    /// inflation as the serial model, so batched-vs-serial comparisons
    /// isolate the batching effect rather than dropping contention
    /// physics. With [`Self::uniform_depth_gate`] on, a decode step may
    /// only join a batch of its own depth class — the pre-ragged
    /// scheduler, whose joins collapse as soon as clients desynchronize.
    fn occupy_batched(
        &mut self,
        id: NodeId,
        arrive: f64,
        compute: f64,
        client: usize,
        class: Option<u64>,
    ) -> f64 {
        /// Marginal cost of one extra fused row, as a fraction of the
        /// full-batch pass (per-row math + KV read vs the weight stream).
        const BATCH_MARGINAL: f64 = 0.07;
        const PS_ALPHA: f64 = 0.02;
        const PS_WINDOW: f64 = 1.0;
        let max_w = self.max_batch_width;
        let (group, own_busy, width, in_flight_class) = {
            let s = self.servers.iter().find(|s| s.id == id).unwrap();
            (s.gpu_group, s.busy_until, s.batch_width_now, s.batch_class)
        };
        if class.is_some() {
            self.decode_step_hops += 1;
        }
        let depth_ok =
            !self.uniform_depth_gate || class.is_none() || in_flight_class == class;
        if arrive < own_busy && width > 0 && width < max_w && depth_ok {
            // join the batch already streaming weights; fused rows share
            // the pass, so no extra PS tax beyond the marginal cost
            let done = own_busy + compute * BATCH_MARGINAL;
            let s = self.server_by_id(id);
            s.busy_until = done;
            s.batch_width_now += 1;
            self.batched_joins += 1;
            if class.is_some() {
                self.decode_joins += 1;
            }
            return done;
        }
        // idle (or width-capped or depth-incompatible) server: full pass,
        // new batch. Co-located traffic on the physical card still
        // inflates the pass exactly as in the serial model.
        let claims = self.group_claims.entry(group).or_default();
        while claims.front().map(|&(t, _)| t < arrive - PS_WINDOW).unwrap_or(false) {
            claims.pop_front();
        }
        let concurrent = claims.iter().filter(|&&(_, c)| c != client).count() as f64;
        claims.push_back((arrive, client));
        let compute = compute * (1.0 + PS_ALPHA * concurrent);
        let solo = self.servers.iter().filter(|s| s.gpu_group == group).count() == 1;
        let group_busy = if solo {
            0.0
        } else {
            *self.group_busy.entry(group).or_insert(0.0)
        };
        let start = arrive.max(own_busy).max(group_busy);
        let done = start + compute;
        {
            let s = self.server_by_id(id);
            s.busy_until = done;
            s.batch_width_now = 1;
            s.batch_class = class;
        }
        if !solo {
            // fused batches still hold the physical card's bandwidth token
            self.group_busy.insert(group, start + compute * 0.33);
        }
        done
    }

    /// Share of decode step-hops that rode an in-flight fused batch —
    /// the sim's batch-occupancy figure for the bench trajectory.
    pub fn decode_occupancy(&self) -> f64 {
        if self.decode_step_hops == 0 {
            0.0
        } else {
            self.decode_joins as f64 / self.decode_step_hops as f64
        }
    }

    /// One client generating `n_steps` tokens after a `prefix_len`
    /// prefix, starting at `t0`. Returns the finish time.
    ///
    /// Timing per step: client overhead (embed + LM head) + for each hop:
    /// one-way message + FIFO wait + span decode compute; + return leg.
    fn run_inference_from(
        &mut self,
        chain: &[ChainHop],
        t0: f64,
        prefix_len: usize,
        n_steps: usize,
        batch: usize,
    ) -> (f64, f64) {
        let msg = step_msg_bytes(&self.profile, batch);
        let mut t = t0;
        // prefill pass (charged once; prefix streams through the chain)
        let prefill_bytes = msg * prefix_len as u64;
        for hop in chain {
            let sid = hop.server;
            let (net_msg, compute) = {
                let s = self.servers.iter().find(|s| s.id == sid).unwrap();
                let net = s.net(&self.profile.default_net);
                (
                    net.message_s(prefill_bytes),
                    s.spec.device.forward_time(
                        hop.end - hop.start,
                        prefix_len * batch,
                        self.profile.flops_per_token_block,
                    ),
                )
            };
            let j = self.jitter(net_msg);
            t += net_msg + j;
            t = self.occupy(sid, t, compute, 0, None);
        }
        let prefill_done = t;
        // decode steps
        let hidden = self.profile.hidden;
        for step in 0..n_steps {
            t += self.profile.client.step_overhead_s;
            for hop in chain {
                let sid = hop.server;
                let (net_msg, compute) = {
                    let s = self.servers.iter().find(|s| s.id == sid).unwrap();
                    let net = s.net(&self.profile.default_net);
                    (
                        net.message_s(msg),
                        {
                            let d = &s.spec.device;
                            // weight stream + KV-cache read that grows
                            // with context (2 x f16 x hidden per cached
                            // token per block) — the seq-128 vs seq-2048
                            // gap in Table 3
                            let n = hop.end - hop.start;
                            let kv_bytes = (prefix_len + step) as f64
                                * 2.0 * 2.0 * hidden as f64 * batch as f64;
                            d.decode_time(n, self.profile.bytes_per_block, batch)
                                + n as f64 * kv_bytes / d.mem_bw
                        },
                    )
                };
                let j = self.jitter(net_msg);
                t += net_msg + j;
                t = self.occupy(sid, t, compute, 0, Some((prefix_len + step) as u64));
            }
            // return leg to the client
            let last = chain.last().unwrap();
            let net = {
                let s = self.servers.iter().find(|s| s.id == last.server).unwrap();
                s.net(&self.profile.default_net).message_s(msg)
            };
            t += net;
        }
        (prefill_done, t)
    }

    fn jitter(&mut self, base: f64) -> f64 {
        let j = self.profile.default_net.jitter;
        if j == 0.0 {
            0.0
        } else {
            base * j * self.rng.f64()
        }
    }

    /// Single-client sequential inference (Table 3 left columns).
    pub fn run_inference(&mut self, prefix_len: usize, n_steps: usize, batch: usize) -> Option<InferenceReport> {
        let chain = self.route(batch)?;
        for s in &mut self.servers {
            s.busy_until = 0.0;
            s.batch_width_now = 0;
            s.batch_class = None;
        }
        let (prefill_done, wall) = self.run_inference_from(&chain, 0.0, prefix_len, n_steps, batch);
        Some(InferenceReport {
            steps: n_steps,
            wall_s: wall,
            // steady-state decode rate (prefill amortizes out in long
            // generations, matching the paper's steps/s)
            steps_per_s: n_steps as f64 / (wall - prefill_done),
            chain_len: chain.len(),
        })
    }

    /// Single-client speculative decoding (wire v8): each round ships
    /// one anchor + up to `k` draft tokens down the chain in ONE
    /// `ProposeVerify` message, the servers verify the m = q+1
    /// positions in a fused pass, and the client keeps the leading run
    /// of drafts that match the model — each draft hits independently
    /// with probability `hit_rate` (drawn from the sim's seeded RNG, so
    /// a given seed replays exactly).
    ///
    /// Cost model per round, mirroring the real execution path:
    /// - hidden-state message grows ×m (one extra token per draft);
    /// - per-hop verify compute is a batch-m decode pass — decode is
    ///   memory-bound, the weight stream is shared across the m
    ///   positions exactly as across fused batch rows — plus the
    ///   per-position KV read;
    /// - the client pays its embed+LM-head overhead once per *sampled*
    ///   position (= committed tokens), identical per token to the
    ///   sequential path.
    ///
    /// The win is paying the chain's round-trip latency once per ROUND
    /// instead of once per TOKEN — exactly the latency-dominated decode
    /// regime of Table 3's bottom rows. At `hit_rate` 0 speculation is
    /// slightly *slower* than sequential decode (same round-trips,
    /// fatter messages): the gate only clears when drafts actually hit.
    pub fn run_inference_speculative(
        &mut self,
        prefix_len: usize,
        n_steps: usize,
        k: usize,
        hit_rate: f64,
    ) -> Option<SpecReport> {
        let chain = self.route(1)?;
        for s in &mut self.servers {
            s.busy_until = 0.0;
            s.batch_width_now = 0;
            s.batch_class = None;
        }
        self.group_busy.clear();
        self.group_claims.clear();
        let (prefill_done, mut t) = self.run_inference_from(&chain, 0.0, prefix_len, 0, 1);
        let msg = step_msg_bytes(&self.profile, 1);
        let hidden = self.profile.hidden;
        let mut produced = 0usize;
        let mut rounds = 0usize;
        let mut proposed = 0usize;
        let mut accepted = 0usize;
        while produced < n_steps {
            let remaining = n_steps - produced;
            // mirror the client's draft budget: never draft past the
            // generation limit, never exceed the wire cap
            let q = k.min(MAX_SPEC_K - 1).min(remaining.saturating_sub(1));
            let m = q + 1;
            for hop in &chain {
                let sid = hop.server;
                let (net_msg, compute) = {
                    let s = self.servers.iter().find(|s| s.id == sid).unwrap();
                    let net = s.net(&self.profile.default_net);
                    let d = &s.spec.device;
                    let n = hop.end - hop.start;
                    // per-position KV read at the depth each candidate
                    // actually occupies
                    let mut kv_t = 0.0;
                    for i in 0..m {
                        let kv_bytes =
                            (prefix_len + produced + i) as f64 * 4.0 * hidden as f64;
                        kv_t += n as f64 * kv_bytes / d.mem_bw;
                    }
                    (
                        net.message_s(msg * m as u64),
                        d.decode_time(n, self.profile.bytes_per_block, m) + kv_t,
                    )
                };
                let j = self.jitter(net_msg);
                t += net_msg + j;
                t = self.occupy(sid, t, compute, 0, None);
            }
            // return leg carries all m output positions
            let last = chain.last().unwrap();
            let net = {
                let s = self.servers.iter().find(|s| s.id == last.server).unwrap();
                s.net(&self.profile.default_net).message_s(msg * m as u64)
            };
            t += net;
            // client samples positions in order until the first miss
            // (or until every draft hit + the bonus position)
            let mut committed = 1usize;
            for _ in 0..q {
                if self.rng.f64() < hit_rate {
                    committed += 1;
                } else {
                    break;
                }
            }
            t += self.profile.client.step_overhead_s * committed as f64;
            proposed += q;
            accepted += committed - 1;
            produced += committed;
            rounds += 1;
        }
        Some(SpecReport {
            tokens: produced,
            rounds,
            wall_s: t,
            tokens_per_s: produced as f64 / (t - prefill_done),
            tokens_per_round: produced as f64 / rounds.max(1) as f64,
            accept_rate: if proposed == 0 {
                0.0
            } else {
                accepted as f64 / proposed as f64
            },
            chain_len: chain.len(),
        })
    }

    /// `n_clients` concurrent sequential-inference clients sharing the
    /// swarm (the §3.3 multi-client experiment), each with a distinct
    /// prompt. Delegates to [`Self::run_inference_concurrent_mix`] with
    /// one template per client and the prefix cache forced off, so the
    /// two workloads share one discrete-event service model. Returns
    /// per-client steady-state decode steps/s.
    pub fn run_inference_concurrent(
        &mut self,
        n_clients: usize,
        prefix_len: usize,
        n_steps: usize,
    ) -> Option<Vec<f64>> {
        let cached = self.prefix_cache;
        self.prefix_cache = false;
        let r = self.run_inference_concurrent_mix(n_clients, prefix_len, n_steps, n_clients);
        self.prefix_cache = cached;
        r.map(|rep| rep.per_client)
    }

    /// `n_clients` concurrent clients whose prompts are drawn from
    /// `n_templates` shared prompt templates (client `c` uses template
    /// `c % n_templates`) — the heavy-traffic scenario the prefix-cache
    /// subsystem targets. With [`Self::prefix_cache`] on, the first
    /// prefill of a template on a server pays full compute and warms it;
    /// later prefills of that template on that server cost
    /// [`PREFIX_HIT_COST`] of the full pass. Decode is unaffected (the
    /// suffix KV is private either way).
    pub fn run_inference_concurrent_mix(
        &mut self,
        n_clients: usize,
        prefix_len: usize,
        n_steps: usize,
        n_templates: usize,
    ) -> Option<SharedMixReport> {
        for s in &mut self.servers {
            s.busy_until = 0.0;
            s.batch_width_now = 0;
            s.batch_class = None;
        }
        self.group_busy.clear();
        self.group_claims.clear();
        let chain = self.route(1)?;
        let msg = step_msg_bytes(&self.profile, 1);
        let hidden = self.profile.hidden;
        let n_hops = chain.len();
        let n_templates = n_templates.max(1);
        let mut warm: std::collections::HashSet<(NodeId, usize)> = Default::default();
        let mut hits = 0usize;

        let mut clock: Vec<f64> = (0..n_clients)
            .map(|c| c as f64 * 0.001 + self.rng.f64() * 2.0)
            .collect();
        let arrival = clock.clone();
        let mut step = vec![0usize; n_clients]; // 0 = prefill
        let mut hop = vec![0usize; n_clients];
        let mut decode_start = vec![0.0f64; n_clients];
        let mut done_at = vec![0.0f64; n_clients];

        loop {
            let Some(c) = (0..n_clients)
                .filter(|&c| step[c] <= n_steps)
                .min_by(|&a, &b| clock[a].total_cmp(&clock[b]))
            else {
                break;
            };
            let h = &chain[hop[c]];
            let sid = h.server;
            let is_prefill = step[c] == 0;
            let (net_msg, compute) = {
                let s = self.servers.iter().find(|s| s.id == sid).unwrap();
                let net = s.net(&self.profile.default_net);
                let d = &s.spec.device;
                let n = h.end - h.start;
                if is_prefill {
                    let full =
                        d.forward_time(n, prefix_len, self.profile.flops_per_token_block);
                    let tmpl = c % n_templates;
                    let compute = if self.prefix_cache && warm.contains(&(sid, tmpl)) {
                        hits += 1;
                        full * PREFIX_HIT_COST
                    } else {
                        warm.insert((sid, tmpl));
                        full
                    };
                    (net.message_s(msg * prefix_len as u64), compute)
                } else {
                    let kv_bytes = (prefix_len + step[c] - 1) as f64 * 4.0 * hidden as f64;
                    (
                        net.message_s(msg),
                        d.decode_time(n, self.profile.bytes_per_block, 1)
                            + n as f64 * kv_bytes / d.mem_bw,
                    )
                }
            };
            let arrive = clock[c] + net_msg * (1.0 + 0.1 * self.rng.f64());
            let class = if is_prefill {
                None
            } else {
                Some((prefix_len + step[c] - 1) as u64)
            };
            clock[c] = self.occupy(sid, arrive, compute, c, class);
            hop[c] += 1;
            if hop[c] == n_hops {
                let last = self
                    .servers
                    .iter()
                    .find(|s| s.id == chain[n_hops - 1].server)
                    .unwrap();
                clock[c] += last.net(&self.profile.default_net).message_s(msg);
                if is_prefill {
                    decode_start[c] = clock[c];
                } else if step[c] == n_steps {
                    done_at[c] = clock[c];
                }
                clock[c] += self.profile.client.step_overhead_s * (0.5 + self.rng.f64());
                step[c] += 1;
                hop[c] = 0;
            }
        }
        self.prefix_hits += hits;
        let per_client: Vec<f64> = (0..n_clients)
            .map(|c| n_steps as f64 / (done_at[c] - decode_start[c]))
            .collect();
        let mean_ttft_s = (0..n_clients)
            .map(|c| decode_start[c] - arrival[c])
            .sum::<f64>()
            / n_clients as f64;
        Some(SharedMixReport { per_client, mean_ttft_s, prefix_hits: hits })
    }

    /// Mixed-length arrival mix — the ragged-batching workload: client
    /// `c` sends a `prefix_lens[c]`-token prompt, so clients prefill for
    /// different durations, desynchronize, and sit at DIFFERENT cache
    /// depths for the whole decode phase. Under
    /// [`Self::uniform_depth_gate`] (the pre-ragged scheduler) almost no
    /// step can join an in-flight batch; with the gate off (ragged
    /// scheduler) any distinct session joins — the occupancy and
    /// aggregate-throughput delta between the two is exactly what
    /// `BENCH_ragged.json` tracks.
    pub fn run_inference_ragged_mix(
        &mut self,
        prefix_lens: &[usize],
        n_steps: usize,
    ) -> Option<RaggedMixReport> {
        let n_clients = prefix_lens.len();
        if n_clients == 0 {
            return None;
        }
        for s in &mut self.servers {
            s.busy_until = 0.0;
            s.batch_width_now = 0;
            s.batch_class = None;
        }
        self.group_busy.clear();
        self.group_claims.clear();
        self.decode_joins = 0;
        self.decode_step_hops = 0;
        let chain = self.route(1)?;
        let msg = step_msg_bytes(&self.profile, 1);
        let hidden = self.profile.hidden;
        let n_hops = chain.len();

        let mut clock: Vec<f64> = (0..n_clients)
            .map(|c| c as f64 * 0.001 + self.rng.f64() * 2.0)
            .collect();
        let arrival = clock.clone();
        let mut step = vec![0usize; n_clients]; // 0 = prefill
        let mut hop = vec![0usize; n_clients];
        let mut decode_start = vec![0.0f64; n_clients];
        let mut done_at = vec![0.0f64; n_clients];

        loop {
            let Some(c) = (0..n_clients)
                .filter(|&c| step[c] <= n_steps)
                .min_by(|&a, &b| clock[a].total_cmp(&clock[b]))
            else {
                break;
            };
            let plen = prefix_lens[c];
            let h = &chain[hop[c]];
            let sid = h.server;
            let is_prefill = step[c] == 0;
            let (net_msg, compute) = {
                let s = self.servers.iter().find(|s| s.id == sid).unwrap();
                let net = s.net(&self.profile.default_net);
                let d = &s.spec.device;
                let n = h.end - h.start;
                if is_prefill {
                    (
                        net.message_s(msg * plen as u64),
                        d.forward_time(n, plen, self.profile.flops_per_token_block),
                    )
                } else {
                    let kv_bytes = (plen + step[c] - 1) as f64 * 4.0 * hidden as f64;
                    (
                        net.message_s(msg),
                        d.decode_time(n, self.profile.bytes_per_block, 1)
                            + n as f64 * kv_bytes / d.mem_bw,
                    )
                }
            };
            let arrive = clock[c] + net_msg * (1.0 + 0.1 * self.rng.f64());
            let class = if is_prefill {
                None
            } else {
                Some((plen + step[c] - 1) as u64)
            };
            clock[c] = self.occupy(sid, arrive, compute, c, class);
            hop[c] += 1;
            if hop[c] == n_hops {
                let last = self
                    .servers
                    .iter()
                    .find(|s| s.id == chain[n_hops - 1].server)
                    .unwrap();
                clock[c] += last.net(&self.profile.default_net).message_s(msg);
                if is_prefill {
                    decode_start[c] = clock[c];
                } else if step[c] == n_steps {
                    done_at[c] = clock[c];
                }
                clock[c] += self.profile.client.step_overhead_s * (0.5 + self.rng.f64());
                step[c] += 1;
                hop[c] = 0;
            }
        }
        let per_client: Vec<f64> = (0..n_clients)
            .map(|c| n_steps as f64 / (done_at[c] - decode_start[c]))
            .collect();
        let mut ttfts: Vec<f64> = (0..n_clients).map(|c| decode_start[c] - arrival[c]).collect();
        ttfts.sort_by(|a, b| a.total_cmp(b));
        let p50_ttft_s = ttfts[ttfts.len() / 2];
        Some(RaggedMixReport {
            aggregate_steps_per_s: per_client.iter().sum(),
            per_client,
            occupancy: self.decode_occupancy(),
            p50_ttft_s,
            decode_joins: self.decode_joins,
        })
    }

    /// Adversarial-tenant fairness: one storming tenant enqueues
    /// `storm_rows` single-row decode sessions at t≈0; `n_well`
    /// well-behaved tenants trickle in behind it, one request each. The
    /// bottleneck fuses up to [`Self::max_batch_width`] rows per round
    /// (round time grows sub-linearly with width — the whole point of
    /// fusion), each request needs `n_steps` rounds, and a request's
    /// TTFT is the completion of its FIRST round. `wfq` selects rows by
    /// per-tenant virtual time (the gateway scheduler's policy,
    /// [`crate::server::StepScheduler`]); otherwise strict FIFO, where
    /// the storm's backlog serializes ahead of every later arrival.
    /// Deterministic given the build seed.
    pub fn run_inference_fair_mix(
        &mut self,
        n_well: usize,
        storm_rows: usize,
        n_steps: usize,
        wfq: bool,
    ) -> Option<FairMixReport> {
        if n_well == 0 || n_steps == 0 {
            return None;
        }
        struct Row {
            tenant: u64,
            ticket: u64,
            arrival: f64,
            steps_left: usize,
            first_tok_at: Option<f64>,
        }
        let width = self.max_batch_width.max(1);
        let mut rows: Vec<Row> = Vec::new();
        let mut ticket = 0u64;
        // the storm lands first (tenant 1), jittered inside ~2ms
        for _ in 0..storm_rows {
            rows.push(Row {
                tenant: 1,
                ticket,
                arrival: self.rng.f64() * 0.002,
                steps_left: n_steps,
                first_tok_at: None,
            });
            ticket += 1;
        }
        // well-behaved tenants (one request each) arrive strictly after
        for i in 0..n_well {
            rows.push(Row {
                tenant: 100 + i as u64,
                ticket,
                arrival: 0.005 + i as f64 * 0.003 + self.rng.f64() * 0.002,
                steps_left: n_steps,
                first_tok_at: None,
            });
            ticket += 1;
        }
        let mut vtime: std::collections::HashMap<u64, u64> = std::collections::HashMap::new();
        let mut now = 0.0f64;
        let mut storm_row_steps = 0usize;
        while rows.iter().any(|r| r.steps_left > 0) {
            let next_arrival = rows
                .iter()
                .filter(|r| r.steps_left > 0)
                .map(|r| r.arrival)
                .min_by(f64::total_cmp)?;
            if now < next_arrival {
                now = next_arrival;
            }
            // assemble one fused round: iterative picks so a WFQ charge
            // lands before the next slot is filled (interleaving tenants
            // instead of draining one). The newcomer floor is latched
            // ONCE per round (exactly like `StepScheduler::take_fair`) —
            // recomputing it per slot would let an incumbent's rising
            // vtime drag the floor up with it, and every tie would then
            // break on ticket toward the storm: WFQ would collapse to
            // FIFO.
            let floor = rows
                .iter()
                .filter(|r| r.steps_left > 0 && r.arrival <= now)
                .filter_map(|r| vtime.get(&r.tenant).copied())
                .min()
                .unwrap_or(0);
            let mut picked: Vec<usize> = Vec::new();
            for _ in 0..width {
                let best = rows
                    .iter()
                    .enumerate()
                    .filter(|(i, r)| {
                        r.steps_left > 0 && r.arrival <= now && !picked.contains(i)
                    })
                    .min_by_key(|(_, r)| {
                        if wfq {
                            (vtime.get(&r.tenant).copied().unwrap_or(floor), r.ticket)
                        } else {
                            // FIFO: arrival order (µs precision keeps
                            // the key integral), ticket tie-break
                            ((r.arrival * 1e6) as u64, r.ticket)
                        }
                    })
                    .map(|(i, r)| (i, r.tenant));
                let Some((i, tenant)) = best else { break };
                if wfq {
                    let vt = vtime.get(&tenant).copied().unwrap_or(floor);
                    vtime.insert(tenant, vt + 1);
                }
                picked.push(i);
            }
            if picked.is_empty() {
                break;
            }
            // fused rounds pay a near-marginal per-row cost: the weight
            // stream dominates, extra rows ride it (the continuous-
            // batching premise the rest of the sim calibrates)
            let round_s = 0.05 + 0.002 * (picked.len() - 1) as f64;
            now += round_s;
            for &i in &picked {
                let r = &mut rows[i];
                if r.steps_left == n_steps {
                    r.first_tok_at = Some(now);
                }
                r.steps_left -= 1;
                if r.tenant == 1 {
                    storm_row_steps += 1;
                }
            }
            // a drained queue resets the virtual-time ledger, exactly
            // like the real scheduler
            if rows.iter().all(|r| r.steps_left == 0 || r.arrival > now) {
                vtime.clear();
            }
        }
        let mut ttfts: Vec<f64> = rows
            .iter()
            .filter(|r| r.tenant != 1)
            .map(|r| r.first_tok_at.unwrap_or(f64::INFINITY) - r.arrival)
            .collect();
        ttfts.sort_by(f64::total_cmp);
        let n = ttfts.len();
        let p99_idx = ((n as f64 * 0.99).ceil() as usize).clamp(1, n) - 1;
        Some(FairMixReport {
            p99_ttft_s: ttfts[p99_idx],
            mean_ttft_s: ttfts.iter().sum::<f64>() / n as f64,
            storm_row_steps,
        })
    }

    /// Parallel forward (Table 3 right columns): `batch` sequences of
    /// `seq_len` tokens, pipelined through the chain in microbatches.
    ///
    /// GPipe bound: wall = fill (one microbatch through all stages) +
    /// (M-1) * slowest stage, stage time = max(compute, transfer).
    pub fn run_forward(&mut self, batch: usize, seq_len: usize, microbatch: usize) -> Option<ForwardReport> {
        let chain = self.route(1)?;
        let m = batch.div_ceil(microbatch);
        let tokens_per_micro = microbatch.min(batch) * seq_len;
        let msg_bytes = hidden_bytes(&self.profile, tokens_per_micro);
        let mut fill = 0.0;
        let mut slowest: f64 = 0.0;
        for hop in &chain {
            let s = self.servers.iter().find(|s| s.id == hop.server).unwrap();
            let net = s.net(&self.profile.default_net);
            let transfer = net.message_s(msg_bytes);
            let compute = s.spec.device.forward_time(
                hop.end - hop.start,
                tokens_per_micro,
                self.profile.flops_per_token_block,
            );
            fill += transfer + compute;
            slowest = slowest.max(transfer.max(compute));
        }
        // return leg of the last microbatch
        let last = self.servers.iter().find(|s| s.id == chain.last().unwrap().server).unwrap();
        let wall = fill
            + (m.saturating_sub(1)) as f64 * slowest
            + last.net(&self.profile.default_net).message_s(msg_bytes);
        let tokens = batch * seq_len;
        Some(ForwardReport { tokens, wall_s: wall, tokens_per_s: tokens as f64 / wall })
    }

    /// Total swarm throughput (balancer objective) — for churn tests.
    pub fn total_throughput(&self) -> f64 {
        balancer::swarm_throughput(&self.coverage())
    }
}

/// Hidden-state bytes for one decode-step message at `batch`.
fn step_msg_bytes(p: &SwarmProfile, batch: usize) -> u64 {
    hidden_bytes(p, batch)
}

/// Hidden-state bytes for `tokens` tokens under the §3.1 codec policy.
fn hidden_bytes(p: &SwarmProfile, tokens: usize) -> u64 {
    quant::wire_bytes(tokens * p.hidden, p.compress_activations)
}

fn self_hidden_bytes(p: &SwarmProfile) -> u64 {
    quant::wire_bytes(p.hidden, p.compress_activations)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profiles::SwarmPreset;

    fn sim(preset: SwarmPreset, net: NetworkProfile) -> SwarmSim {
        SwarmSim::build(preset.build(net, true), 0)
    }

    #[test]
    fn three_a100_cover_all_blocks() {
        let s = sim(SwarmPreset::ThreeA100, NetworkProfile::GBIT_5MS);
        assert!(s.total_throughput() > 0.0, "every block covered");
        assert_eq!(s.views().len(), 3);
    }

    #[test]
    fn inference_in_paper_ballpark_3xa100() {
        // paper: 1.71 steps/s @ 1 Gbit 5ms, seq 128. Shape target: same
        // order of magnitude (1-4 steps/s).
        let mut s = sim(SwarmPreset::ThreeA100, NetworkProfile::GBIT_5MS);
        let r = s.run_inference(128, 64, 1).unwrap();
        assert!(
            (0.8..4.0).contains(&r.steps_per_s),
            "steps/s {} out of ballpark",
            r.steps_per_s
        );
    }

    #[test]
    fn rtt_hurts_more_than_bandwidth() {
        // paper Table 3: inference "does not depend much on bandwidth
        // [...] but degrades with higher latency"
        let f = |net| {
            let mut s = sim(SwarmPreset::TwelveVirtual, net);
            s.run_inference(128, 32, 1).unwrap().steps_per_s
        };
        let gbit = f(NetworkProfile::GBIT_5MS);
        let mbit = f(NetworkProfile::MBIT100_5MS);
        let slow = f(NetworkProfile::MBIT100_100MS);
        assert!((mbit / gbit) > 0.8, "bandwidth barely matters: {mbit} vs {gbit}");
        assert!(slow / mbit < 0.75, "latency hurts: {slow} vs {mbit}");
    }

    #[test]
    fn twelve_virtual_slower_than_three_physical() {
        let f = |p| {
            let mut s = sim(p, NetworkProfile::MBIT100_100MS);
            s.run_inference(128, 32, 1).unwrap().steps_per_s
        };
        assert!(f(SwarmPreset::TwelveVirtual) < f(SwarmPreset::ThreeA100));
    }

    #[test]
    fn forward_benefits_from_bandwidth() {
        // parallel forward IS bandwidth sensitive (Table 3 right cols),
        // unlike single-batch decode (previous test)
        let f = |net, compress| {
            let mut s = SwarmSim::build(SwarmPreset::TwelveVirtual.build(net, compress), 0);
            s.run_forward(64, 128, 2).unwrap().tokens_per_s
        };
        // with §3.1 compression the sensitivity is damped but present
        let fast = f(NetworkProfile::GBIT_5MS, true);
        let slow = f(NetworkProfile::MBIT100_5MS, true);
        assert!(fast / slow > 1.05, "{fast} vs {slow}");
        // raw f32 activations make the bandwidth dependence stark
        let fast_raw = f(NetworkProfile::GBIT_5MS, false);
        let slow_raw = f(NetworkProfile::MBIT100_5MS, false);
        assert!(fast_raw / slow_raw > 1.3, "{fast_raw} vs {slow_raw}");
    }

    #[test]
    fn eight_clients_degrade_gracefully() {
        // paper: 8 concurrent clients -> ~20% per-client slowdown on the
        // 12-virtual 100Mbit/100ms swarm
        let mut s = sim(SwarmPreset::TwelveVirtual, NetworkProfile::MBIT100_100MS);
        let solo = s.run_inference(128, 16, 1).unwrap().steps_per_s;
        let many = s.run_inference_concurrent(8, 128, 16).unwrap();
        let mean: f64 = many.iter().sum::<f64>() / many.len() as f64;
        let slowdown = 1.0 - mean / solo;
        assert!(
            (0.02..0.70).contains(&slowdown),
            "slowdown {slowdown} (solo {solo}, mean {mean})"
        );
    }

    #[test]
    fn continuous_batching_lifts_aggregate_throughput() {
        // same swarm, same 8 clients; the only change is whether servers
        // fuse concurrent decode steps. Aggregate tokens/s must improve,
        // and must beat the sequential per-session baseline (= solo rate,
        // since sequential sessions run one at a time).
        let run = |batched: bool| {
            let mut s = sim(SwarmPreset::TwelveVirtual, NetworkProfile::MBIT100_100MS);
            s.continuous_batching = batched;
            let rates = s.run_inference_concurrent(8, 128, 16).unwrap();
            (rates.iter().sum::<f64>(), s.batched_joins)
        };
        let (agg_serial, joins_serial) = run(false);
        let (agg_batched, joins_batched) = run(true);
        assert_eq!(joins_serial, 0);
        assert!(joins_batched > 0, "no step ever joined a batch");
        assert!(
            agg_batched > agg_serial,
            "batching must lift aggregate throughput: {agg_batched} vs {agg_serial}"
        );
        let mut s = sim(SwarmPreset::TwelveVirtual, NetworkProfile::MBIT100_100MS);
        let solo = s.run_inference(128, 16, 1).unwrap().steps_per_s;
        assert!(
            agg_batched > 2.0 * solo,
            "8 batched clients must beat the sequential baseline by far: {agg_batched} vs solo {solo}"
        );
    }

    /// The ragged-batching claim at sim scale: with mixed-length
    /// prompts, the pre-ragged same-depth join gate almost never fires
    /// (clients desynchronize during their different-length prefills),
    /// while the ragged scheduler keeps fusing — higher occupancy AND
    /// higher aggregate throughput, from the same arrival trace.
    #[test]
    fn ragged_mix_lifts_occupancy_and_aggregate() {
        let lens: Vec<usize> = vec![32, 48, 64, 96, 128, 160, 192, 224];
        let run = |gate: bool| {
            let mut s = sim(SwarmPreset::TwelveVirtual, NetworkProfile::MBIT100_100MS);
            s.continuous_batching = true;
            s.uniform_depth_gate = gate;
            s.run_inference_ragged_mix(&lens, 16).unwrap()
        };
        let old = run(true); // pre-ragged scheduler
        let new = run(false); // ragged scheduler
        assert!(
            new.occupancy > old.occupancy,
            "ragged must lift occupancy: {} vs {}",
            new.occupancy,
            old.occupancy
        );
        assert!(
            new.aggregate_steps_per_s > old.aggregate_steps_per_s,
            "ragged must lift aggregate steps/s: {} vs {}",
            new.aggregate_steps_per_s,
            old.aggregate_steps_per_s
        );
        assert!(new.decode_joins > old.decode_joins);
        assert!(new.p50_ttft_s > 0.0 && old.p50_ttft_s > 0.0);
        assert_eq!(new.per_client.len(), lens.len());
        // without continuous batching the ragged mix still completes
        let mut s = sim(SwarmPreset::TwelveVirtual, NetworkProfile::MBIT100_100MS);
        let serial = s.run_inference_ragged_mix(&lens, 8).unwrap();
        assert_eq!(serial.decode_joins, 0);
        assert_eq!(serial.occupancy, 0.0);
    }

    #[test]
    fn speculative_decode_doubles_throughput_on_slow_links() {
        // the PR's acceptance gate at sim scale: k=6 drafts with a 0.6
        // per-draft hit rate must at least double committed tokens/s on
        // the high-latency swarm, where round-trips dominate decode
        // (Table 3 bottom row) — the regime speculation targets.
        let mut s = sim(SwarmPreset::TwelveVirtual, NetworkProfile::MBIT100_100MS);
        let base = s.run_inference(128, 64, 1).unwrap().steps_per_s;
        let mut s = sim(SwarmPreset::TwelveVirtual, NetworkProfile::MBIT100_100MS);
        let spec = s.run_inference_speculative(128, 1024, 6, 0.6).unwrap();
        assert_eq!(spec.tokens, 1024, "must commit exactly n_steps");
        assert!(spec.rounds < 1024, "rounds {} must beat one-per-token", spec.rounds);
        assert!(
            (1.8..3.0).contains(&spec.tokens_per_round),
            "tokens/round {} off the k=6 p=0.6 expectation (~2.4)",
            spec.tokens_per_round
        );
        assert!(
            spec.tokens_per_s >= 2.0 * base,
            "speculation must double decode: {} vs sequential {}",
            spec.tokens_per_s,
            base
        );
        // measured acceptance < per-draft hit rate (rounds stop at the
        // first miss, so tail drafts are proposed but never accepted)
        assert!(spec.accept_rate > 0.0 && spec.accept_rate < 0.6, "{}", spec.accept_rate);
    }

    #[test]
    fn speculative_decode_degrades_gracefully_with_hit_rate() {
        let run = |hit: f64| {
            let mut s = sim(SwarmPreset::TwelveVirtual, NetworkProfile::MBIT100_100MS);
            s.run_inference_speculative(128, 256, 6, hit).unwrap()
        };
        let zero = run(0.0);
        let mid = run(0.6);
        let high = run(0.9);
        // all-miss: one committed token per round, no drafts accepted
        assert_eq!(zero.tokens_per_round, 1.0);
        assert_eq!(zero.accept_rate, 0.0);
        assert_eq!(zero.rounds, 256);
        // throughput rises monotonically with the hit rate
        assert!(mid.tokens_per_s > 1.5 * zero.tokens_per_s);
        assert!(high.tokens_per_s > mid.tokens_per_s);
        // at zero acceptance speculation must NOT look faster than the
        // sequential path (it ships fatter messages for nothing)
        let mut s = sim(SwarmPreset::TwelveVirtual, NetworkProfile::MBIT100_100MS);
        let base = s.run_inference(128, 64, 1).unwrap().steps_per_s;
        assert!(zero.tokens_per_s <= base * 1.02, "{} vs {}", zero.tokens_per_s, base);
        // k = 0 degenerates to plain sequential stepping
        let mut s = sim(SwarmPreset::TwelveVirtual, NetworkProfile::MBIT100_100MS);
        let k0 = s.run_inference_speculative(128, 32, 0, 0.9).unwrap();
        assert_eq!(k0.rounds, 32);
        assert_eq!(k0.tokens_per_round, 1.0);
        assert_eq!(k0.accept_rate, 0.0);
    }

    #[test]
    fn shared_prefix_cache_cuts_time_to_first_token() {
        // 8 clients all sending the same system prompt: with the prefix
        // cache on, every prefill after the first per (server, template)
        // is nearly free, so mean time-to-first-token drops; steady-state
        // decode is untouched.
        let run = |cached: bool| {
            let mut s = sim(SwarmPreset::TwelveVirtual, NetworkProfile::MBIT100_100MS);
            s.prefix_cache = cached;
            s.run_inference_concurrent_mix(8, 128, 16, 1).unwrap()
        };
        let cold = run(false);
        let warm = run(true);
        assert_eq!(cold.prefix_hits, 0);
        assert!(warm.prefix_hits > 0, "repeat templates must hit");
        assert!(
            warm.mean_ttft_s < cold.mean_ttft_s * 0.9,
            "prefix cache must cut TTFT: warm {} vs cold {}",
            warm.mean_ttft_s,
            cold.mean_ttft_s
        );
        // unique prompts (8 templates for 8 clients): no benefit claimed
        let mut s = sim(SwarmPreset::TwelveVirtual, NetworkProfile::MBIT100_100MS);
        s.prefix_cache = true;
        let unique = s.run_inference_concurrent_mix(8, 128, 16, 8).unwrap();
        assert_eq!(unique.prefix_hits, 0, "distinct templates never alias");
    }

    #[test]
    fn marginal_pages_shrink_with_sharing() {
        // the acceptance arithmetic: 8 clients sharing a 128-token prompt
        // (16-token pages, 4 blocks), each decoding 8 tokens
        let full = pages_per_session(128, 8, 16, 4, false);
        let marginal = pages_per_session(128, 8, 16, 4, true);
        assert_eq!(full, 2 * 4 * 9);
        assert_eq!(marginal, 2 * 4, "suffix-only cost");
        assert!(marginal * 8 < full);
        // 1 shared + 7 marginal sessions vs 8 private sessions
        let pool_shared = full + 7 * marginal;
        let pool_private = 8 * full;
        assert!(pool_shared * 4 < pool_private);
        // degenerate cases
        assert_eq!(pages_per_session(128, 0, 16, 4, true), 0);
        assert!(pages_per_session(120, 8, 16, 4, true) >= 2 * 4);
    }

    #[test]
    fn churn_gap_closed_by_rebalance() {
        let mut s = sim(SwarmPreset::TwelveVirtual, NetworkProfile::GBIT_5MS);
        assert!(s.total_throughput() > 0.0);
        // kill every server covering block 0
        let victims: Vec<usize> = s
            .servers
            .iter()
            .enumerate()
            .filter(|(_, srv)| srv.span.start == 0)
            .map(|(i, _)| i)
            .collect();
        assert!(!victims.is_empty());
        for v in victims {
            s.kill(v);
        }
        assert_eq!(s.total_throughput(), 0.0, "gap opened");
        let moves = s.rebalance();
        assert!(moves > 0);
        assert!(s.total_throughput() > 0.0, "gap closed by rebalancing");
        assert!(s.run_inference(128, 4, 1).is_some());
    }

    #[test]
    fn compression_helps_on_slow_links() {
        let p_on = SwarmPreset::TwelveVirtual.build(NetworkProfile::MBIT100_5MS, true);
        let p_off = SwarmPreset::TwelveVirtual.build(NetworkProfile::MBIT100_5MS, false);
        let mut on = SwarmSim::build(p_on, 0);
        let mut off = SwarmSim::build(p_off, 0);
        let t_on = on.run_forward(64, 128, 8).unwrap().tokens_per_s;
        let t_off = off.run_forward(64, 128, 8).unwrap().tokens_per_s;
        assert!(t_on > t_off * 1.3, "compressed {t_on} vs raw {t_off}");
    }
}
