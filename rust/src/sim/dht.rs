//! Deterministic Kademlia swarm simulator — the third [`Rpc`] backend
//! (next to the in-memory test net and the framed-TCP
//! [`crate::dht::node`]).
//!
//! Unlike the test net (which gives every node a *complete* view, so
//! lookups trivially terminate in one round), nodes here join the way
//! real nodes do: one at a time, through a bootstrap peer, keeping only
//! what the iterative self-lookup and inbound traffic teach them. The
//! resulting tables are sparse and the O(log n) iterative behavior is
//! real — which is the point: the simulator meters **RPC count (hops)**
//! and a **virtual clock** (every RPC charges one hop latency), so
//! `ci/bench.sh` can track lookup cost and churn-convergence time at
//! swarm sizes (hundreds of nodes) that would be slow and flaky as real
//! socket tests.

use crate::config::Rng;
use crate::dht::{
    iterative_find_node, iterative_find_value, iterative_store, NodeId, Record, RoutingTable,
    Rpc, Storage, K,
};
use std::cell::{Cell, RefCell};
use std::collections::HashMap;

struct SimNode {
    table: RoutingTable,
    store: Storage,
    alive: bool,
}

/// A simulated Kademlia swarm with metered RPCs and a virtual clock.
pub struct SimDhtNet {
    nodes: RefCell<HashMap<NodeId, SimNode>>,
    /// Seconds one request/response round trip costs on the virtual
    /// clock (the paper's real-world profile is ~0.1 s RTT).
    pub hop_latency_s: f64,
    clock_s: Cell<f64>,
    rpcs: Cell<u64>,
    pings: Cell<u64>,
}

/// One metered lookup: RPCs issued and virtual time charged.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LookupCost {
    pub rpcs: u64,
    pub latency_s: f64,
    pub found: usize,
}

impl SimDhtNet {
    /// Grow an `n`-node swarm by realistic joins: node 0 is the seed;
    /// every later node bootstraps through it with an iterative
    /// self-lookup, keeps the closest peers it met, and is inserted
    /// into *their* tables (the inbound-contact half of Kademlia that
    /// the abstract [`Rpc`] cannot express). Returns the net and the
    /// node ids in join order.
    pub fn build(n: usize, seed: u64, hop_latency_s: f64) -> (Self, Vec<NodeId>) {
        let mut rng = Rng::new(seed);
        let ids: Vec<NodeId> = (0..n).map(|_| NodeId::random(&mut rng)).collect();
        let net = SimDhtNet {
            nodes: RefCell::new(HashMap::new()),
            hop_latency_s,
            clock_s: Cell::new(0.0),
            rpcs: Cell::new(0),
            pings: Cell::new(0),
        };
        net.nodes.borrow_mut().insert(
            ids[0],
            SimNode { table: RoutingTable::new(ids[0]), store: Storage::new(), alive: true },
        );
        for &id in &ids[1..] {
            net.join(id, ids[0]);
        }
        (net, ids)
    }

    /// Join `id` through `seed`: the canonical iterative self-lookup.
    fn join(&self, id: NodeId, seed: NodeId) {
        self.nodes.borrow_mut().insert(
            id,
            SimNode { table: RoutingTable::new(id), store: Storage::new(), alive: true },
        );
        let met = iterative_find_node(self, &[seed], id);
        let mut nodes = self.nodes.borrow_mut();
        // the joiner keeps the seed + everyone the lookup met...
        {
            let me = nodes.get_mut(&id).unwrap();
            me.table.insert(seed, |_| true);
            for &peer in &met {
                me.table.insert(peer, |_| true);
            }
        }
        // ...and the contacted nodes learn the joiner (inbound contact;
        // full buckets keep their old entries — everyone here is alive)
        for peer in met.iter().chain(std::iter::once(&seed)) {
            if let Some(p) = nodes.get_mut(peer) {
                p.table.insert(id, |_| true);
            }
        }
    }

    /// Virtual seconds elapsed (each RPC charges one hop).
    pub fn clock_s(&self) -> f64 {
        self.clock_s.get()
    }

    /// Virtual clock in ms — the record timestamp base.
    pub fn now_ms(&self) -> u64 {
        (self.clock_s.get() * 1000.0) as u64
    }

    /// Advance the virtual clock without traffic (idle time, e.g.
    /// waiting out a TTL).
    pub fn advance_s(&self, s: f64) {
        self.clock_s.set(self.clock_s.get() + s);
    }

    pub fn rpc_count(&self) -> u64 {
        self.rpcs.get()
    }

    /// Pings issued (the iterative lookups must issue none — their
    /// queries double as the liveness probe; see `dht::Rpc::find_node`).
    pub fn ping_count(&self) -> u64 {
        self.pings.get()
    }

    pub fn kill(&self, id: NodeId) {
        if let Some(n) = self.nodes.borrow_mut().get_mut(&id) {
            n.alive = false;
        }
    }

    pub fn alive(&self) -> usize {
        self.nodes.borrow().values().filter(|n| n.alive).count()
    }

    fn charge(&self) {
        self.rpcs.set(self.rpcs.get() + 1);
        self.clock_s.set(self.clock_s.get() + self.hop_latency_s);
    }

    /// Meter one `iterative_find_value` from `seeds`.
    pub fn measure_lookup(&self, seeds: &[NodeId], key: NodeId) -> LookupCost {
        let (r0, c0) = (self.rpcs.get(), self.clock_s.get());
        let found = iterative_find_value(self, seeds, key);
        LookupCost {
            rpcs: self.rpcs.get() - r0,
            latency_s: self.clock_s.get() - c0,
            found: found.len(),
        }
    }

    /// Publish `payload` under `key` from `publisher` (replicated to the
    /// K closest live nodes); returns stores performed.
    pub fn publish(
        &self,
        publisher: NodeId,
        seeds: &[NodeId],
        key: NodeId,
        payload: Vec<u8>,
        ttl_ms: u64,
    ) -> usize {
        let rec = Record::new(publisher, payload, self.now_ms(), ttl_ms);
        iterative_store(self, seeds, key, rec)
    }
}

impl Rpc for SimDhtNet {
    fn find_node(&self, callee: NodeId, target: NodeId) -> Option<Vec<NodeId>> {
        self.charge();
        let nodes = self.nodes.borrow();
        match nodes.get(&callee) {
            Some(n) if n.alive => Some(n.table.closest(target, K)),
            _ => None,
        }
    }

    fn find_value(&self, callee: NodeId, key: NodeId) -> Option<Vec<Record>> {
        self.charge();
        let now = self.now_ms();
        let nodes = self.nodes.borrow();
        let n = nodes.get(&callee)?;
        if !n.alive {
            return None;
        }
        let recs = n.store.get(&key, now);
        if recs.is_empty() {
            None
        } else {
            Some(recs)
        }
    }

    fn store(&self, callee: NodeId, key: NodeId, rec: Record) -> bool {
        self.charge();
        let mut nodes = self.nodes.borrow_mut();
        if let Some(n) = nodes.get_mut(&callee) {
            if n.alive {
                n.store.put(key, rec);
                return true;
            }
        }
        false
    }

    fn ping(&self, callee: NodeId) -> bool {
        self.charge();
        self.pings.set(self.pings.get() + 1);
        self.nodes.borrow().get(&callee).map(|n| n.alive).unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_join_tables_still_resolve() {
        let (net, ids) = SimDhtNet::build(64, 1, 0.05);
        // tables are sparse (nobody holds the whole swarm)...
        let max_table = ids
            .iter()
            .map(|id| net.nodes.borrow().get(id).unwrap().table.len())
            .max()
            .unwrap();
        assert!(max_table < 63, "join must not produce a full mesh");
        // ...yet every published key resolves from an arbitrary node
        for i in 0..8 {
            let key = NodeId::from_name(&format!("bloom/block/{i}"));
            net.publish(ids[i], &[ids[0]], key, vec![i as u8], 600_000);
            let cost = net.measure_lookup(&[ids[40 + i]], key);
            assert!(cost.found >= 1, "key {i} unresolvable");
            assert!(cost.rpcs > 0 && cost.latency_s > 0.0);
        }
    }

    /// Satellite: the iterative lookups must not ping-preflight — the
    /// query RPC doubles as the liveness probe, so a lookup costs one
    /// `find_node` (plus at most one `find_value`) per contacted peer
    /// instead of two dials each.
    #[test]
    fn lookups_issue_no_ping_preflight() {
        let (net, ids) = SimDhtNet::build(64, 5, 0.05);
        let key = NodeId::from_name("bloom/block/2");
        net.publish(ids[3], &[ids[0]], key, b"srv".to_vec(), 600_000);
        let pings_before = net.ping_count();
        let cost = net.measure_lookup(&[ids[40]], key);
        assert!(cost.found >= 1);
        assert_eq!(net.ping_count(), pings_before, "lookup must issue zero pings");
        // ...and a pure node lookup too
        let r0 = net.rpc_count();
        let _ = iterative_find_node(&net, &[ids[10]], NodeId::from_name("probe"));
        let dials = net.rpc_count() - r0;
        assert_eq!(net.ping_count(), pings_before, "find_node lookup must issue zero pings");
        assert!(dials > 0);
        // every dial is a find_node — with the old preflight this same
        // lookup cost 2x (ping + find_node per contacted peer)
    }

    #[test]
    fn lookup_cost_grows_sublinearly() {
        let cost_at = |n: usize| {
            let (net, ids) = SimDhtNet::build(n, 7, 0.05);
            let key = NodeId::from_name("probe");
            net.publish(ids[1], &[ids[0]], key, b"x".to_vec(), 600_000);
            let mut total = 0u64;
            for i in 0..8 {
                total += net.measure_lookup(&[ids[(i * 13 + 3) % n]], key).rpcs;
            }
            total as f64 / 8.0
        };
        let small = cost_at(32);
        let big = cost_at(256);
        // 8x the swarm must cost far less than 8x the RPCs (Kademlia is
        // O(log n); allow generous slack for table-quality variance)
        assert!(
            big < small * 4.0,
            "lookup cost scaled linearly: {small:.1} rpcs @32 vs {big:.1} @256"
        );
    }

    /// ROADMAP satellite: bucket-refresh lookups on a timer keep a
    /// long-idle node routable through churn. A client whose table was
    /// populated long ago refreshes its stale buckets (learning the
    /// CURRENT swarm members); when every originally-known peer then
    /// dies, it still resolves fresh records. A control client with the
    /// identical starting table and no refresh is stranded.
    #[test]
    fn bucket_refresh_keeps_long_idle_node_resolving_after_churn() {
        use crate::dht::refresh_stale_buckets;
        use std::sync::Mutex;

        let (net, ids) = SimDhtNet::build(48, 13, 0.05);
        let mut rng = Rng::new(99);
        let me = NodeId::random(&mut rng);
        // both clients knew the same 5 peers, at t=0
        let known: Vec<NodeId> = ids[5..10].to_vec();
        let refreshed = Mutex::new(RoutingTable::new(me));
        let control = Mutex::new(RoutingTable::new(me));
        for &p in &known {
            refreshed.lock().unwrap().insert_at(p, 0, |_| true);
            control.lock().unwrap().insert_at(p, 0, |_| true);
        }
        // the refreshed client's maintenance timer fires while its old
        // peers are still alive: stale buckets (idle > 60 s) get lookups
        net.advance_s(120.0);
        let now = net.now_ms();
        let n = refresh_stale_buckets(&net, &refreshed, now, 60_000, 256);
        assert!(n > 0, "idle buckets must be refresh candidates");
        let grown = refreshed.lock().unwrap().len();
        assert!(grown > known.len(), "refresh must learn current swarm members");

        // churn: every originally-known peer dies, then a fresh record
        // is published on the surviving swarm
        for &p in &known {
            net.kill(p);
        }
        let key = NodeId::from_name("bloom/block/9");
        net.publish(ids[20], &[ids[0]], key, b"srv".to_vec(), 600_000);

        // the control client's whole world view is dead: unresolvable
        let control_seeds = control.lock().unwrap().closest(key, K);
        assert_eq!(
            net.measure_lookup(&control_seeds, key).found,
            0,
            "control (no refresh) must be stranded — all its seeds died"
        );
        // the refreshed client routes through the peers it learned
        let seeds = refreshed.lock().unwrap().closest(key, K);
        assert!(
            net.measure_lookup(&seeds, key).found >= 1,
            "refreshed client must still resolve after churn"
        );
        // a second refresh with everything fresh is a no-op
        assert_eq!(refresh_stale_buckets(&net, &refreshed, net.now_ms(), 600_000, 256), 0);
    }

    #[test]
    fn churn_expiry_and_republish_converge() {
        let (net, ids) = SimDhtNet::build(48, 3, 0.05);
        let key = NodeId::from_name("bloom/block/0");
        let ttl = 30_000u64;
        net.publish(ids[1], &[ids[0]], key, b"srv".to_vec(), ttl);
        assert!(net.measure_lookup(&[ids[20]], key).found >= 1);
        // kill a third of the swarm (replicas included, maybe) — but
        // keep the seed, the publisher, and the querying node alive so
        // the scenario tests record churn, not total partition
        let mut rng = Rng::new(9);
        for _ in 0..16 {
            let victim = ids[2 + rng.usize_below(46)];
            if victim != ids[20] {
                net.kill(victim);
            }
        }
        // TTL passes without republish: the record ages out everywhere
        net.advance_s(ttl as f64 / 1000.0 + 1.0);
        assert_eq!(net.measure_lookup(&[ids[20]], key).found, 0, "expired");
        // a republish from the (live) publisher restores resolution and
        // its virtual cost is the convergence time
        let t0 = net.clock_s();
        net.publish(ids[1], &[ids[0]], key, b"srv".to_vec(), ttl);
        let cost = net.measure_lookup(&[ids[20]], key);
        assert!(cost.found >= 1, "republish must restore the record");
        assert!(net.clock_s() - t0 > 0.0);
    }
}
