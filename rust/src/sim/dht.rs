//! Deterministic Kademlia swarm simulator — the third [`Rpc`] backend
//! (next to the in-memory test net and the framed-TCP
//! [`crate::dht::node`]).
//!
//! Unlike the test net (which gives every node a *complete* view, so
//! lookups trivially terminate in one round), nodes here join the way
//! real nodes do: one at a time, through a bootstrap peer, keeping only
//! what the iterative self-lookup and inbound traffic teach them. The
//! resulting tables are sparse and the O(log n) iterative behavior is
//! real — which is the point: the simulator meters **RPC count (hops)**
//! and a **virtual clock** (every RPC charges one hop latency), so
//! `ci/bench.sh` can track lookup cost and churn-convergence time at
//! swarm sizes (hundreds of nodes) that would be slow and flaky as real
//! socket tests.

use crate::config::Rng;
use crate::dht::{
    iterative_find_node, iterative_find_value, iterative_store, NodeId, Record, RoutingTable,
    Rpc, Storage, K,
};
use std::cell::{Cell, RefCell};
use std::collections::HashMap;

struct SimNode {
    table: RoutingTable,
    store: Storage,
    alive: bool,
}

/// A simulated Kademlia swarm with metered RPCs and a virtual clock.
pub struct SimDhtNet {
    nodes: RefCell<HashMap<NodeId, SimNode>>,
    /// Seconds one request/response round trip costs on the virtual
    /// clock (the paper's real-world profile is ~0.1 s RTT).
    pub hop_latency_s: f64,
    clock_s: Cell<f64>,
    rpcs: Cell<u64>,
    pings: Cell<u64>,
}

/// One metered lookup: RPCs issued and virtual time charged.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LookupCost {
    pub rpcs: u64,
    pub latency_s: f64,
    pub found: usize,
}

impl SimDhtNet {
    /// Grow an `n`-node swarm by realistic joins: node 0 is the seed;
    /// every later node bootstraps through it with an iterative
    /// self-lookup, keeps the closest peers it met, and is inserted
    /// into *their* tables (the inbound-contact half of Kademlia that
    /// the abstract [`Rpc`] cannot express). Returns the net and the
    /// node ids in join order.
    pub fn build(n: usize, seed: u64, hop_latency_s: f64) -> (Self, Vec<NodeId>) {
        let mut rng = Rng::new(seed);
        let ids: Vec<NodeId> = (0..n).map(|_| NodeId::random(&mut rng)).collect();
        let net = SimDhtNet {
            nodes: RefCell::new(HashMap::new()),
            hop_latency_s,
            clock_s: Cell::new(0.0),
            rpcs: Cell::new(0),
            pings: Cell::new(0),
        };
        net.nodes.borrow_mut().insert(
            ids[0],
            SimNode { table: RoutingTable::new(ids[0]), store: Storage::new(), alive: true },
        );
        for &id in &ids[1..] {
            net.join(id, ids[0]);
        }
        (net, ids)
    }

    /// Join `id` through `seed`: the canonical iterative self-lookup.
    fn join(&self, id: NodeId, seed: NodeId) {
        self.nodes.borrow_mut().insert(
            id,
            SimNode { table: RoutingTable::new(id), store: Storage::new(), alive: true },
        );
        let met = iterative_find_node(self, &[seed], id);
        let mut nodes = self.nodes.borrow_mut();
        // the joiner keeps the seed + everyone the lookup met...
        {
            let me = nodes.get_mut(&id).unwrap();
            me.table.insert(seed, |_| true);
            for &peer in &met {
                me.table.insert(peer, |_| true);
            }
        }
        // ...and the contacted nodes learn the joiner (inbound contact;
        // full buckets keep their old entries — everyone here is alive)
        for peer in met.iter().chain(std::iter::once(&seed)) {
            if let Some(p) = nodes.get_mut(peer) {
                p.table.insert(id, |_| true);
            }
        }
    }

    /// Virtual seconds elapsed (each RPC charges one hop).
    pub fn clock_s(&self) -> f64 {
        self.clock_s.get()
    }

    /// Virtual clock in ms — the record timestamp base.
    pub fn now_ms(&self) -> u64 {
        (self.clock_s.get() * 1000.0) as u64
    }

    /// Advance the virtual clock without traffic (idle time, e.g.
    /// waiting out a TTL).
    pub fn advance_s(&self, s: f64) {
        self.clock_s.set(self.clock_s.get() + s);
    }

    pub fn rpc_count(&self) -> u64 {
        self.rpcs.get()
    }

    /// Pings issued (the iterative lookups must issue none — their
    /// queries double as the liveness probe; see `dht::Rpc::find_node`).
    pub fn ping_count(&self) -> u64 {
        self.pings.get()
    }

    pub fn kill(&self, id: NodeId) {
        if let Some(n) = self.nodes.borrow_mut().get_mut(&id) {
            n.alive = false;
        }
    }

    pub fn alive(&self) -> usize {
        self.nodes.borrow().values().filter(|n| n.alive).count()
    }

    fn charge(&self) {
        self.rpcs.set(self.rpcs.get() + 1);
        self.clock_s.set(self.clock_s.get() + self.hop_latency_s);
    }

    /// Meter one `iterative_find_value` from `seeds`.
    pub fn measure_lookup(&self, seeds: &[NodeId], key: NodeId) -> LookupCost {
        let (r0, c0) = (self.rpcs.get(), self.clock_s.get());
        let found = iterative_find_value(self, seeds, key);
        LookupCost {
            rpcs: self.rpcs.get() - r0,
            latency_s: self.clock_s.get() - c0,
            found: found.len(),
        }
    }

    /// Publish `payload` under `key` from `publisher` (replicated to the
    /// K closest live nodes); returns stores performed.
    pub fn publish(
        &self,
        publisher: NodeId,
        seeds: &[NodeId],
        key: NodeId,
        payload: Vec<u8>,
        ttl_ms: u64,
    ) -> usize {
        let rec = Record::new(publisher, payload, self.now_ms(), ttl_ms);
        iterative_store(self, seeds, key, rec)
    }
}

impl Rpc for SimDhtNet {
    fn find_node(&self, callee: NodeId, target: NodeId) -> Option<Vec<NodeId>> {
        self.charge();
        let nodes = self.nodes.borrow();
        match nodes.get(&callee) {
            Some(n) if n.alive => Some(n.table.closest(target, K)),
            _ => None,
        }
    }

    fn find_value(&self, callee: NodeId, key: NodeId) -> Option<Vec<Record>> {
        self.charge();
        let now = self.now_ms();
        let nodes = self.nodes.borrow();
        let n = nodes.get(&callee)?;
        if !n.alive {
            return None;
        }
        let recs = n.store.get(&key, now);
        if recs.is_empty() {
            None
        } else {
            Some(recs)
        }
    }

    fn store(&self, callee: NodeId, key: NodeId, rec: Record) -> bool {
        self.charge();
        let mut nodes = self.nodes.borrow_mut();
        if let Some(n) = nodes.get_mut(&callee) {
            if n.alive {
                n.store.put(key, rec);
                return true;
            }
        }
        false
    }

    fn ping(&self, callee: NodeId) -> bool {
        self.charge();
        self.pings.set(self.pings.get() + 1);
        self.nodes.borrow().get(&callee).map(|n| n.alive).unwrap_or(false)
    }
}

// ---------------------------------------------------------------------------
// Rebalancing-under-churn model
// ---------------------------------------------------------------------------

/// Workload for [`run_rebalance_churn`]: a swarm of virtual servers on a
/// virtual clock, shrinking through a sustained departure phase and then
/// growing back (the diurnal pattern public swarms actually see). The
/// same seeded event schedule drives two arms — one running the
/// distributed rebalancing protocol of [`crate::rebalance`] (deterministic
/// greedy planner + hysteresis + dwell, at most one elected mover per
/// snapshot), one a static-assignment control whose servers pick a span
/// once at join ([`balancer::choose_join_span`]) and never move — so the
/// aggregate-throughput difference is attributable to rebalancing alone.
#[derive(Debug, Clone)]
pub struct ChurnWorkload {
    pub n_blocks: usize,
    /// Starting (and final) population.
    pub n_servers: usize,
    /// Total simulated seconds; the first half is the departure phase,
    /// the second half the recovery phase.
    pub horizon_s: f64,
    /// Evaluation/sampling period of the virtual clock.
    pub tick_s: f64,
    /// Probability of one churn event (a leave in phase 1, a join in
    /// phase 2) at each tick.
    pub churn_prob: f64,
    /// Hysteresis bar for the rebalancing arm (see
    /// [`balancer::plan_rebalance`]).
    pub min_gain_ratio: f64,
    /// Seconds a server that just moved sits out of planning.
    pub dwell_s: f64,
    pub seed: u64,
}

impl Default for ChurnWorkload {
    fn default() -> Self {
        ChurnWorkload {
            n_blocks: 96,
            n_servers: 256,
            horizon_s: 600.0,
            tick_s: 5.0,
            churn_prob: 0.8,
            min_gain_ratio: 0.05,
            dwell_s: 30.0,
            seed: 0xC0FFEE,
        }
    }
}

/// Outcome of one [`run_rebalance_churn`] comparison.
#[derive(Debug, Clone, Copy)]
pub struct ChurnOutcome {
    /// Time-averaged swarm throughput (bottleneck-block steps/s proxy)
    /// with live rebalancing on.
    pub rebalance_steps_per_s: f64,
    /// Same metric for the static-assignment control.
    pub static_steps_per_s: f64,
    /// `rebalance_steps_per_s / static_steps_per_s`.
    pub gain: f64,
    /// Span moves the rebalancing arm executed.
    pub moves: usize,
    /// Fraction of ticks the control spent with an uncovered block.
    pub static_dead_frac: f64,
    /// Same for the rebalancing arm.
    pub rebalance_dead_frac: f64,
}

/// The shared churn schedule: tick index → event. Precomputed once so
/// both arms replay byte-identical populations.
enum ChurnEvent {
    /// Kill the `pick % alive`-th live server.
    Leave { pick: u64 },
    /// A fresh server joins with this capacity and per-block weight
    /// (span chosen by each arm's own policy at apply time).
    Join { capacity: usize, weight: f64 },
}

struct ChurnServer {
    span: std::ops::Range<usize>,
    weight: f64,
    alive: bool,
    /// Virtual time of this server's last own move (dwell hysteresis).
    moved_at_s: f64,
    /// Set for the tick in which the server is (re)loading blocks after
    /// a move — it contributes nothing to that tick's throughput, so the
    /// model charges a real (if coarse) cost per move.
    loading: bool,
}

use crate::coordinator::balancer;

fn churn_coverage(servers: &[ChurnServer], n_blocks: usize) -> balancer::BlockCoverage {
    let mut cov = balancer::BlockCoverage::new(n_blocks);
    for s in servers.iter().filter(|s| s.alive && !s.loading) {
        cov.add_span(s.span.clone(), s.weight);
    }
    cov
}

fn churn_arm(w: &ChurnWorkload, schedule: &[(usize, ChurnEvent)], rebalance: bool) -> (f64, usize, f64) {
    let mut servers: Vec<ChurnServer> = Vec::new();
    let mut join = |servers: &mut Vec<ChurnServer>, capacity: usize, weight: f64| {
        let cov = churn_coverage(servers, w.n_blocks);
        let span = balancer::choose_join_span(&cov, capacity);
        servers.push(ChurnServer {
            span,
            weight,
            alive: true,
            moved_at_s: f64::NEG_INFINITY,
            loading: false,
        });
    };
    // initial population: the same greedy join sequence in both arms
    {
        let mut boot = Rng::new(w.seed ^ 0xB007);
        for _ in 0..w.n_servers {
            let capacity = 4 + boot.usize_below(5); // 4..=8 blocks
            let weight = boot.range_f64(0.5, 2.0);
            join(&mut servers, capacity, weight);
        }
    }
    let ticks = (w.horizon_s / w.tick_s).ceil() as usize;
    let mut ev = schedule.iter().peekable();
    let mut integral = 0.0;
    let mut dead_ticks = 0usize;
    let mut moves = 0usize;
    for t in 0..ticks {
        let now_s = t as f64 * w.tick_s;
        while let Some((tick, event)) = ev.peek() {
            if *tick > t {
                break;
            }
            match event {
                ChurnEvent::Leave { pick } => {
                    let alive: Vec<usize> = servers
                        .iter()
                        .enumerate()
                        .filter(|(_, s)| s.alive)
                        .map(|(i, _)| i)
                        .collect();
                    if !alive.is_empty() {
                        servers[alive[(*pick % alive.len() as u64) as usize]].alive = false;
                    }
                }
                ChurnEvent::Join { capacity, weight } => {
                    join(&mut servers, *capacity, *weight);
                }
            }
            ev.next();
        }
        if rebalance {
            // the distributed protocol: everyone plans over the same
            // full snapshot with the same deterministic greedy policy,
            // so at most ONE server is elected per snapshot — and if the
            // elected mover is still inside its dwell window, nobody
            // moves this tick (dwell is the mover's own hysteresis, not
            // a hole in everyone else's coverage view)
            let idx: Vec<usize> = servers
                .iter()
                .enumerate()
                .filter(|(_, s)| s.alive)
                .map(|(i, _)| i)
                .collect();
            let spans: Vec<(std::ops::Range<usize>, f64)> =
                idx.iter().map(|&i| (servers[i].span.clone(), servers[i].weight)).collect();
            if let Some(mv) = balancer::plan_rebalance(w.n_blocks, &spans, w.min_gain_ratio) {
                let s = &mut servers[idx[mv.server_idx]];
                if now_s - s.moved_at_s >= w.dwell_s {
                    s.span = mv.to;
                    s.moved_at_s = now_s;
                    s.loading = true;
                    moves += 1;
                }
            }
        }
        let tp = balancer::swarm_throughput(&churn_coverage(&servers, w.n_blocks));
        if tp <= 0.0 {
            dead_ticks += 1;
        }
        integral += tp * w.tick_s;
        for s in servers.iter_mut() {
            s.loading = false;
        }
    }
    (integral / w.horizon_s, moves, dead_ticks as f64 / ticks as f64)
}

/// Run the rebalancing-vs-static churn comparison (see
/// [`ChurnWorkload`]). Fully deterministic for a given workload: virtual
/// clock, seeded PRNG, no wall time.
pub fn run_rebalance_churn(w: &ChurnWorkload) -> ChurnOutcome {
    // one shared schedule: departures while the swarm shrinks, joins
    // (fresh capacities/weights) while it recovers
    let mut rng = Rng::new(w.seed);
    let ticks = (w.horizon_s / w.tick_s).ceil() as usize;
    let mut schedule: Vec<(usize, ChurnEvent)> = Vec::new();
    let mut departed = 0usize;
    for t in 0..ticks {
        if rng.f64() >= w.churn_prob {
            continue;
        }
        if t < ticks / 2 {
            schedule.push((t, ChurnEvent::Leave { pick: rng.next_u64() }));
            departed += 1;
        } else if departed > 0 {
            let capacity = 4 + rng.usize_below(5);
            let weight = rng.range_f64(0.5, 2.0);
            schedule.push((t, ChurnEvent::Join { capacity, weight }));
            departed -= 1;
        }
    }
    let (stat, _, stat_dead) = churn_arm(w, &schedule, false);
    let (reb, moves, reb_dead) = churn_arm(w, &schedule, true);
    ChurnOutcome {
        rebalance_steps_per_s: reb,
        static_steps_per_s: stat,
        gain: if stat > 0.0 { reb / stat } else { f64::INFINITY },
        moves,
        static_dead_frac: stat_dead,
        rebalance_dead_frac: reb_dead,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_join_tables_still_resolve() {
        let (net, ids) = SimDhtNet::build(64, 1, 0.05);
        // tables are sparse (nobody holds the whole swarm)...
        let max_table = ids
            .iter()
            .map(|id| net.nodes.borrow().get(id).unwrap().table.len())
            .max()
            .unwrap();
        assert!(max_table < 63, "join must not produce a full mesh");
        // ...yet every published key resolves from an arbitrary node
        for i in 0..8 {
            let key = NodeId::from_name(&format!("bloom/block/{i}"));
            net.publish(ids[i], &[ids[0]], key, vec![i as u8], 600_000);
            let cost = net.measure_lookup(&[ids[40 + i]], key);
            assert!(cost.found >= 1, "key {i} unresolvable");
            assert!(cost.rpcs > 0 && cost.latency_s > 0.0);
        }
    }

    /// Satellite: the iterative lookups must not ping-preflight — the
    /// query RPC doubles as the liveness probe, so a lookup costs one
    /// `find_node` (plus at most one `find_value`) per contacted peer
    /// instead of two dials each.
    #[test]
    fn lookups_issue_no_ping_preflight() {
        let (net, ids) = SimDhtNet::build(64, 5, 0.05);
        let key = NodeId::from_name("bloom/block/2");
        net.publish(ids[3], &[ids[0]], key, b"srv".to_vec(), 600_000);
        let pings_before = net.ping_count();
        let cost = net.measure_lookup(&[ids[40]], key);
        assert!(cost.found >= 1);
        assert_eq!(net.ping_count(), pings_before, "lookup must issue zero pings");
        // ...and a pure node lookup too
        let r0 = net.rpc_count();
        let _ = iterative_find_node(&net, &[ids[10]], NodeId::from_name("probe"));
        let dials = net.rpc_count() - r0;
        assert_eq!(net.ping_count(), pings_before, "find_node lookup must issue zero pings");
        assert!(dials > 0);
        // every dial is a find_node — with the old preflight this same
        // lookup cost 2x (ping + find_node per contacted peer)
    }

    #[test]
    fn lookup_cost_grows_sublinearly() {
        let cost_at = |n: usize| {
            let (net, ids) = SimDhtNet::build(n, 7, 0.05);
            let key = NodeId::from_name("probe");
            net.publish(ids[1], &[ids[0]], key, b"x".to_vec(), 600_000);
            let mut total = 0u64;
            for i in 0..8 {
                total += net.measure_lookup(&[ids[(i * 13 + 3) % n]], key).rpcs;
            }
            total as f64 / 8.0
        };
        let small = cost_at(32);
        let big = cost_at(256);
        // 8x the swarm must cost far less than 8x the RPCs (Kademlia is
        // O(log n); allow generous slack for table-quality variance)
        assert!(
            big < small * 4.0,
            "lookup cost scaled linearly: {small:.1} rpcs @32 vs {big:.1} @256"
        );
    }

    /// ROADMAP satellite: bucket-refresh lookups on a timer keep a
    /// long-idle node routable through churn. A client whose table was
    /// populated long ago refreshes its stale buckets (learning the
    /// CURRENT swarm members); when every originally-known peer then
    /// dies, it still resolves fresh records. A control client with the
    /// identical starting table and no refresh is stranded.
    #[test]
    fn bucket_refresh_keeps_long_idle_node_resolving_after_churn() {
        use crate::dht::refresh_stale_buckets;
        use std::sync::Mutex;

        let (net, ids) = SimDhtNet::build(48, 13, 0.05);
        let mut rng = Rng::new(99);
        let me = NodeId::random(&mut rng);
        // both clients knew the same 5 peers, at t=0
        let known: Vec<NodeId> = ids[5..10].to_vec();
        let refreshed = Mutex::new(RoutingTable::new(me));
        let control = Mutex::new(RoutingTable::new(me));
        for &p in &known {
            refreshed.lock().unwrap().insert_at(p, 0, |_| true);
            control.lock().unwrap().insert_at(p, 0, |_| true);
        }
        // the refreshed client's maintenance timer fires while its old
        // peers are still alive: stale buckets (idle > 60 s) get lookups
        net.advance_s(120.0);
        let now = net.now_ms();
        let n = refresh_stale_buckets(&net, &refreshed, now, 60_000, 256);
        assert!(n > 0, "idle buckets must be refresh candidates");
        let grown = refreshed.lock().unwrap().len();
        assert!(grown > known.len(), "refresh must learn current swarm members");

        // churn: every originally-known peer dies, then a fresh record
        // is published on the surviving swarm
        for &p in &known {
            net.kill(p);
        }
        let key = NodeId::from_name("bloom/block/9");
        net.publish(ids[20], &[ids[0]], key, b"srv".to_vec(), 600_000);

        // the control client's whole world view is dead: unresolvable
        let control_seeds = control.lock().unwrap().closest(key, K);
        assert_eq!(
            net.measure_lookup(&control_seeds, key).found,
            0,
            "control (no refresh) must be stranded — all its seeds died"
        );
        // the refreshed client routes through the peers it learned
        let seeds = refreshed.lock().unwrap().closest(key, K);
        assert!(
            net.measure_lookup(&seeds, key).found >= 1,
            "refreshed client must still resolve after churn"
        );
        // a second refresh with everything fresh is a no-op
        assert_eq!(refresh_stale_buckets(&net, &refreshed, net.now_ms(), 600_000, 256), 0);
    }

    #[test]
    fn rebalance_churn_model_is_deterministic() {
        let w = ChurnWorkload {
            n_servers: 64,
            n_blocks: 48,
            horizon_s: 200.0,
            ..Default::default()
        };
        let a = run_rebalance_churn(&w);
        let b = run_rebalance_churn(&w);
        assert_eq!(a.rebalance_steps_per_s, b.rebalance_steps_per_s);
        assert_eq!(a.static_steps_per_s, b.static_steps_per_s);
        assert_eq!(a.moves, b.moves);
        assert!(a.static_steps_per_s > 0.0, "control must not be born dead");
    }

    #[test]
    fn rebalancing_helps_under_churn_at_small_scale() {
        let w = ChurnWorkload {
            n_servers: 64,
            n_blocks: 48,
            horizon_s: 300.0,
            ..Default::default()
        };
        let out = run_rebalance_churn(&w);
        assert!(out.moves > 0, "the departure phase must trigger span moves");
        assert!(
            out.rebalance_steps_per_s >= out.static_steps_per_s,
            "rebalancing must not lose to the static control: {:.3} vs {:.3}",
            out.rebalance_steps_per_s,
            out.static_steps_per_s
        );
    }

    #[test]
    fn churn_expiry_and_republish_converge() {
        let (net, ids) = SimDhtNet::build(48, 3, 0.05);
        let key = NodeId::from_name("bloom/block/0");
        let ttl = 30_000u64;
        net.publish(ids[1], &[ids[0]], key, b"srv".to_vec(), ttl);
        assert!(net.measure_lookup(&[ids[20]], key).found >= 1);
        // kill a third of the swarm (replicas included, maybe) — but
        // keep the seed, the publisher, and the querying node alive so
        // the scenario tests record churn, not total partition
        let mut rng = Rng::new(9);
        for _ in 0..16 {
            let victim = ids[2 + rng.usize_below(46)];
            if victim != ids[20] {
                net.kill(victim);
            }
        }
        // TTL passes without republish: the record ages out everywhere
        net.advance_s(ttl as f64 / 1000.0 + 1.0);
        assert_eq!(net.measure_lookup(&[ids[20]], key).found, 0, "expired");
        // a republish from the (live) publisher restores resolution and
        // its virtual cost is the convergence time
        let t0 = net.clock_s();
        net.publish(ids[1], &[ids[0]], key, b"srv".to_vec(), ttl);
        let cost = net.measure_lookup(&[ids[20]], key);
        assert!(cost.found >= 1, "republish must restore the record");
        assert!(net.clock_s() - t0 > 0.0);
    }
}
