//! Distributed parameter-efficient fine-tuning (§2.2, Figure 4).
//!
//! "The core principle of fine-tuning in a distributed network is that
//! clients 'own' trained parameters while servers host original
//! pretrained layers. Servers can run backpropagation through their
//! layers and return gradients with respect to activations, but they do
//! not update the server-side parameters."
//!
//! This module implements the client side of soft prompt tuning for
//! sequence classification: trainable prompt embeddings prepended to the
//! input, a trainable linear head on the last hidden state, forward
//! through the server chain, backward through the reversed chain, and a
//! local Adam step. All heavy math (blocks fwd/bwd) runs on servers via
//! AOT artifacts; the prompt/head math is tiny and lives here in plain
//! Rust (it would be a <1% slice of any profile).
//!
//! Since the streaming-API redesign the trainer talks to the swarm
//! through [`ActivationBackend`] — either [`ChainActivations`] (direct
//! [`ChainClient`] routing, in-process or TCP) or
//! [`crate::api::http`]-backed `HttpActivations` below, which drives
//! the public `POST /api/v1/forward` / `backward` endpoints. The same
//! trainer runs against both, so the prompt-tuning example exercises
//! the real public API path.

use crate::config::Rng;
use crate::coordinator::routing::{self, RouteQuery};
use crate::coordinator::session::ChainClient;
use crate::error::{Error, Result};
use crate::model::tensor::Tensor;
use std::sync::Mutex;

/// The two swarm calls prompt tuning needs: a stateless chain forward
/// over raw activations, and the matching backward returning the
/// gradient wrt the input. Implementations: [`ChainActivations`]
/// (direct swarm access) and [`HttpActivations`] (the public HTTP API).
pub trait ActivationBackend {
    /// [B,S,H] activations -> final-layer hidden states [B,S,H].
    fn forward(&self, x: &Tensor) -> Result<Tensor>;
    /// Gradient wrt `x` given the gradient wrt `forward(x)`.
    fn backward(&self, x: &Tensor, grad_out: &Tensor) -> Result<Tensor>;
}

/// One remembered forward pass: the chain used and each span's input,
/// so a matching `backward` skips recomputing the forward.
struct ForwardTrace {
    x0: Tensor,
    chain: Vec<crate::coordinator::routing::ChainHop>,
    span_inputs: Vec<Tensor>,
}

/// [`ActivationBackend`] over any [`ChainClient`]: routes a chain,
/// pipes activations through every span, and remembers the last
/// forward's span inputs so the paired backward replays them instead of
/// re-running the forward.
pub struct ChainActivations<'a, C: ChainClient> {
    pub swarm: &'a C,
    pub route: RouteQuery,
    trace: Mutex<Option<ForwardTrace>>,
}

impl<'a, C: ChainClient> ChainActivations<'a, C> {
    pub fn new(swarm: &'a C, route: RouteQuery) -> Self {
        ChainActivations { swarm, route, trace: Mutex::new(None) }
    }
}

impl<'a, C: ChainClient> ActivationBackend for ChainActivations<'a, C> {
    fn forward(&self, x: &Tensor) -> Result<Tensor> {
        let servers = self.swarm.discover();
        let (chain, _) = routing::find_chain(&servers, &self.route)
            .ok_or_else(|| Error::NoRoute("no chain".into()))?;
        let mut span_inputs = Vec::with_capacity(chain.len());
        let mut h = x.clone();
        for hop in &chain {
            span_inputs.push(h.clone());
            h = self.swarm.forward(hop.server, &h)?;
        }
        *self.trace.lock().unwrap() =
            Some(ForwardTrace { x0: x.clone(), chain, span_inputs });
        Ok(h)
    }

    fn backward(&self, x: &Tensor, grad_out: &Tensor) -> Result<Tensor> {
        // reuse the remembered span inputs when this backward pairs with
        // the last forward (the common train-step pattern); anything
        // else falls back to the generic route-and-replay helper
        let trace = self.trace.lock().unwrap().take();
        match trace {
            Some(t) if t.x0.shape == x.shape && t.x0.data == x.data => {
                let mut g = grad_out.clone();
                for (i, hop) in t.chain.iter().enumerate().rev() {
                    g = self.swarm.backward(hop.server, &t.span_inputs[i], &g)?;
                }
                Ok(g)
            }
            _ => crate::coordinator::session::chain_backward(
                self.swarm,
                &self.route,
                x,
                grad_out,
            ),
        }
    }
}

/// [`ActivationBackend`] over the public HTTP API: `POST
/// /api/v1/forward` / `POST /api/v1/backward` with raw `[B,S,H]`
/// activations — the paper's "exposes hidden states" research workload
/// driven end-to-end through the served surface.
///
/// Speaks the binary tensor transport (`application/x-petals-tensor`,
/// little-endian f32 + dims header) in BOTH directions: activations are
/// the hot payload of the fine-tuning loop and the binary framing moves
/// them at 4 bytes/element instead of ~20 of decimal text. The two
/// framings are bit-exact, so training trajectories are identical
/// either way — the JSON path stays available via
/// [`HttpActivations::json`] for debugging against older gateways.
pub struct HttpActivations {
    /// `host:port` of a running [`crate::api::ApiServer`].
    pub addr: String,
}

impl HttpActivations {
    /// A JSON-transport variant of the same backend (legacy gateways,
    /// wire debugging). Bit-identical results, more bytes on the wire.
    pub fn json(addr: String) -> HttpJsonActivations {
        HttpJsonActivations { addr }
    }

    fn post_tensors(&self, path: &str, tensors: &[&Tensor]) -> Result<Tensor> {
        let body = crate::api::types::tensors_to_binary(tensors);
        let (status, ctype, reply) = crate::api::stream::http_post_bytes(
            &self.addr,
            path,
            crate::api::types::TENSOR_CONTENT_TYPE,
            crate::api::types::TENSOR_CONTENT_TYPE,
            &body,
        )?;
        if status != 200 {
            return Err(Error::Protocol(format!(
                "{path} failed ({status}): {}",
                String::from_utf8_lossy(&reply)
            )));
        }
        if !ctype.starts_with(crate::api::types::TENSOR_CONTENT_TYPE) {
            return Err(Error::Protocol(format!(
                "{path} replied {ctype:?}, not the binary tensor transport"
            )));
        }
        let mut out = crate::api::types::tensors_from_binary(&reply)?;
        match out.len() {
            1 => Ok(out.pop().expect("len checked")),
            n => Err(Error::Protocol(format!("{path} returned {n} tensors, want 1"))),
        }
    }
}

impl ActivationBackend for HttpActivations {
    fn forward(&self, x: &Tensor) -> Result<Tensor> {
        self.post_tensors("/api/v1/forward", &[x])
    }

    fn backward(&self, x: &Tensor, grad_out: &Tensor) -> Result<Tensor> {
        self.post_tensors("/api/v1/backward", &[x, grad_out])
    }
}

/// JSON-transport [`ActivationBackend`] (see [`HttpActivations::json`]).
pub struct HttpJsonActivations {
    pub addr: String,
}

impl ActivationBackend for HttpJsonActivations {
    fn forward(&self, x: &Tensor) -> Result<Tensor> {
        let body = format!(
            "{{\"embeds\":{}}}",
            crate::api::types::tensor_to_json(x).render()
        );
        let reply = crate::api::http::http_post(&self.addr, "/api/v1/forward", &body)?;
        let v = crate::config::json::Value::parse(&reply)?;
        if let Some(err) = v.opt("error") {
            return Err(Error::Protocol(format!("forward failed: {}", err.render())));
        }
        crate::api::types::tensor_from_json(v.get("hidden")?)
    }

    fn backward(&self, x: &Tensor, grad_out: &Tensor) -> Result<Tensor> {
        let body = format!(
            "{{\"embeds\":{},\"grad\":{}}}",
            crate::api::types::tensor_to_json(x).render(),
            crate::api::types::tensor_to_json(grad_out).render()
        );
        let reply = crate::api::http::http_post(&self.addr, "/api/v1/backward", &body)?;
        let v = crate::config::json::Value::parse(&reply)?;
        if let Some(err) = v.opt("error") {
            return Err(Error::Protocol(format!("backward failed: {}", err.render())));
        }
        crate::api::types::tensor_from_json(v.get("grad")?)
    }
}

/// Trainable soft prompts + classifier head (client-owned).
pub struct PromptTuner {
    /// [n_prompts, H] trainable prompt embeddings.
    pub prompts: Vec<f32>,
    pub n_prompts: usize,
    pub hidden: usize,
    /// [H, n_classes] classifier weights + [n_classes] bias.
    pub head_w: Vec<f32>,
    pub head_b: Vec<f32>,
    pub n_classes: usize,
    opt: Adam,
}

/// Minimal Adam over the client-owned parameter vector.
struct Adam {
    lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    t: i32,
    m: Vec<f32>,
    v: Vec<f32>,
}

impl Adam {
    fn new(n: usize, lr: f32) -> Self {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, t: 0, m: vec![0.0; n], v: vec![0.0; n] }
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t);
        let b2t = 1.0 - self.beta2.powi(self.t);
        for i in 0..params.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grads[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * grads[i] * grads[i];
            let mh = self.m[i] / b1t;
            let vh = self.v[i] / b2t;
            params[i] -= self.lr * mh / (vh.sqrt() + self.eps);
        }
    }
}

/// One training step's outcome.
#[derive(Debug, Clone)]
pub struct StepReport {
    pub loss: f32,
    pub accuracy: f32,
}

impl PromptTuner {
    /// Fresh tuner: `n_prompts` trainable prompt embeddings (~N(0, 0.02))
    /// plus a zero-initialized linear head, optimized with Adam at `lr`.
    pub fn new(n_prompts: usize, hidden: usize, n_classes: usize, lr: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut prompts = vec![0f32; n_prompts * hidden];
        for p in prompts.iter_mut() {
            *p = (rng.normal() as f32) * 0.02;
        }
        let mut head_w = vec![0f32; hidden * n_classes];
        for w in head_w.iter_mut() {
            *w = (rng.normal() as f32) * 0.02;
        }
        let head_b = vec![0f32; n_classes];
        let n_params = n_prompts * hidden + hidden * n_classes + n_classes;
        PromptTuner {
            prompts,
            n_prompts,
            hidden,
            head_w,
            head_b,
            n_classes,
            opt: Adam::new(n_params, lr),
        }
    }

    /// Splice trainable prompts in front of token embeddings:
    /// embeds [B,S,H] -> [B,S,H] with positions 0..n_prompts replaced.
    /// (The sequence budget S already reserves the prompt slots.)
    pub fn apply_prompts(&self, embeds: &Tensor) -> Tensor {
        let (b, s, h) = (embeds.shape[0], embeds.shape[1], embeds.shape[2]);
        assert!(self.n_prompts <= s);
        assert_eq!(h, self.hidden);
        let mut out = embeds.clone();
        let data = out.as_f32_mut();
        for bi in 0..b {
            let off = bi * s * h;
            data[off..off + self.n_prompts * h].copy_from_slice(&self.prompts);
        }
        out
    }

    /// Classifier forward: last valid hidden [B,H] -> logits [B,C].
    pub fn head_forward(&self, feats: &[f32], batch: usize) -> Vec<f32> {
        let (h, c) = (self.hidden, self.n_classes);
        let mut logits = vec![0f32; batch * c];
        for bi in 0..batch {
            for ci in 0..c {
                let mut acc = self.head_b[ci];
                for k in 0..h {
                    acc += feats[bi * h + k] * self.head_w[k * c + ci];
                }
                logits[bi * c + ci] = acc;
            }
        }
        logits
    }

    /// Softmax cross-entropy: returns (loss, dlogits, accuracy).
    pub fn loss_and_grad(logits: &[f32], labels: &[usize], n_classes: usize) -> (f32, Vec<f32>, f32) {
        let b = labels.len();
        let mut dlogits = vec![0f32; logits.len()];
        let mut loss = 0f32;
        let mut correct = 0usize;
        for bi in 0..b {
            let row = &logits[bi * n_classes..(bi + 1) * n_classes];
            let mx = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
            let exps: Vec<f32> = row.iter().map(|&x| (x - mx).exp()).collect();
            let z: f32 = exps.iter().sum();
            let probs: Vec<f32> = exps.iter().map(|&e| e / z).collect();
            loss -= (probs[labels[bi]].max(1e-12)).ln();
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0;
            if pred == labels[bi] {
                correct += 1;
            }
            for ci in 0..n_classes {
                let y = if ci == labels[bi] { 1.0 } else { 0.0 };
                dlogits[bi * n_classes + ci] = (probs[ci] - y) / b as f32;
            }
        }
        (loss / b as f32, dlogits, correct as f32 / b as f32)
    }

    /// One full distributed training step (Figure 4's inner loop):
    ///
    /// 1. embeds (client) -> splice prompts -> chain forward (servers)
    /// 2. classifier head + loss (client)
    /// 3. chain backward in reverse (servers return activation grads)
    /// 4. prompt grads = grad at prompt positions; head grads local
    /// 5. Adam step on client-owned params only
    ///
    /// `last_valid` is the sequence position whose hidden state feeds the
    /// classifier (last real token). The backend is either direct swarm
    /// access ([`ChainActivations`]) or the public HTTP API
    /// ([`HttpActivations`]).
    pub fn train_step<B: ActivationBackend>(
        &mut self,
        backend: &B,
        embeds: &Tensor,
        labels: &[usize],
        last_valid: usize,
    ) -> Result<StepReport> {
        let (b, s, h) = (embeds.shape[0], embeds.shape[1], embeds.shape[2]);
        if b != labels.len() {
            return Err(Error::Shape(format!("batch {b} vs {} labels", labels.len())));
        }

        // ---- forward ----
        let x0 = self.apply_prompts(embeds);
        let hcur = backend.forward(&x0)?;

        // ---- head + loss ----
        let feats: Vec<f32> = {
            let src = hcur.as_f32();
            let mut v = Vec::with_capacity(b * h);
            for bi in 0..b {
                let off = (bi * s + last_valid) * h;
                v.extend_from_slice(&src[off..off + h]);
            }
            v
        };
        let logits = self.head_forward(&feats, b);
        let (loss, dlogits, accuracy) = Self::loss_and_grad(&logits, labels, self.n_classes);

        // ---- head grads (local) ----
        let c = self.n_classes;
        let mut d_head_w = vec![0f32; h * c];
        let mut d_head_b = vec![0f32; c];
        let mut d_feats = vec![0f32; b * h];
        for bi in 0..b {
            for ci in 0..c {
                let g = dlogits[bi * c + ci];
                d_head_b[ci] += g;
                for k in 0..h {
                    d_head_w[k * c + ci] += feats[bi * h + k] * g;
                    d_feats[bi * h + k] += self.head_w[k * c + ci] * g;
                }
            }
        }

        // ---- backward through the chain (reverse order) ----
        let mut dh = Tensor::zeros(&[b, s, h], crate::model::tensor::DType::F32);
        {
            let dst = dh.as_f32_mut();
            for bi in 0..b {
                let off = (bi * s + last_valid) * h;
                dst[off..off + h].copy_from_slice(&d_feats[bi * h..(bi + 1) * h]);
            }
        }
        let dh = backend.backward(&x0, &dh)?;

        // ---- prompt grads = grad at prompt positions, summed over batch
        let mut d_prompts = vec![0f32; self.n_prompts * h];
        {
            let src = dh.as_f32();
            for bi in 0..b {
                let off = bi * s * h;
                for j in 0..self.n_prompts * h {
                    d_prompts[j] += src[off + j];
                }
            }
        }

        // ---- Adam over the concatenated client-owned params ----
        let mut params: Vec<f32> = Vec::new();
        params.extend_from_slice(&self.prompts);
        params.extend_from_slice(&self.head_w);
        params.extend_from_slice(&self.head_b);
        let mut grads: Vec<f32> = Vec::new();
        grads.extend_from_slice(&d_prompts);
        grads.extend_from_slice(&d_head_w);
        grads.extend_from_slice(&d_head_b);
        self.opt.step(&mut params, &grads);
        let (p, rest) = params.split_at(self.prompts.len());
        let (w, bias) = rest.split_at(self.head_w.len());
        self.prompts.copy_from_slice(p);
        self.head_w.copy_from_slice(w);
        self.head_b.copy_from_slice(bias);

        Ok(StepReport { loss, accuracy })
    }

    /// Serialize client-owned parameters (for the hub, §2.3).
    pub fn export_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        for &v in self.prompts.iter().chain(&self.head_w).chain(&self.head_b) {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_decreases_quadratic() {
        // sanity: Adam on f(x) = x^2 converges toward 0
        let mut adam = Adam::new(1, 0.1);
        let mut x = vec![3.0f32];
        for _ in 0..200 {
            let g = vec![2.0 * x[0]];
            adam.step(&mut x, &g);
        }
        assert!(x[0].abs() < 0.1, "{}", x[0]);
    }

    #[test]
    fn loss_grad_sums_to_zero_rows() {
        let logits = vec![1.0, 2.0, 0.5, -1.0, 0.0, 1.0];
        let (_, d, _) = PromptTuner::loss_and_grad(&logits, &[0, 2], 3);
        for bi in 0..2 {
            let s: f32 = d[bi * 3..(bi + 1) * 3].iter().sum();
            assert!(s.abs() < 1e-6, "softmax grad rows sum to 0");
        }
    }

    #[test]
    fn apply_prompts_overwrites_prefix_only() {
        let mut t = PromptTuner::new(2, 4, 2, 0.01, 0);
        t.prompts = vec![9.0; 8];
        let embeds = Tensor::from_f32(&[1, 3, 4], &[1.0; 12]);
        let out = t.apply_prompts(&embeds);
        let o = out.as_f32();
        assert!(o[..8].iter().all(|&v| v == 9.0));
        assert!(o[8..].iter().all(|&v| v == 1.0));
    }

    #[test]
    fn head_forward_shapes_and_bias() {
        let mut t = PromptTuner::new(1, 3, 2, 0.01, 0);
        t.head_w = vec![0.0; 6];
        t.head_b = vec![0.5, -0.5];
        let logits = t.head_forward(&[1.0, 2.0, 3.0], 1);
        assert_eq!(logits, vec![0.5, -0.5]);
    }

    /// Learning works end-to-end against a linearly separable toy task
    /// through a *fake* chain (identity servers) — exercises the full
    /// distributed-backprop protocol without PJRT cost.
    #[test]
    fn prompt_tuning_learns_separable_task() {
        use crate::coordinator::routing::ServerView;
        use crate::dht::NodeId;

        struct Identity;
        impl ChainClient for Identity {
            fn discover(&self) -> Vec<ServerView> {
                vec![ServerView {
                    id: NodeId::from_name("id"),
                    start: 0,
                    end: 1,
                    latency_s: 0.0,
                    bandwidth_bps: 1e9,
                    span_compute_s: 0.0,
                    queue_depth: 0,
                    free_ratio: 1.0,
                    prefix_fps: vec![],
                    p50_step_us: 0,
                    measured_step_s: None,
                    measured_age_s: 0.0,
                }]
            }
            fn open_session(&self, _: NodeId, _: u64, _: usize, _: usize, _: usize) -> Result<()> {
                Ok(())
            }
            fn prefill(&self, _: NodeId, _: u64, h: &Tensor) -> Result<Tensor> {
                Ok(h.clone())
            }
            fn step(&self, _: NodeId, _: u64, _: usize, h: &Tensor) -> Result<Tensor> {
                Ok(h.clone())
            }
            fn close_session(&self, _: NodeId, _: u64) {}
            fn forward(&self, _: NodeId, h: &Tensor) -> Result<Tensor> {
                Ok(h.clone())
            }
            fn backward(&self, _: NodeId, _: &Tensor, g: &Tensor) -> Result<Tensor> {
                Ok(g.clone())
            }
        }

        let h = 8;
        let b = 8;
        let s = 4;
        let mut tuner = PromptTuner::new(1, h, 2, 0.05, 0);
        let route = RouteQuery {
            n_blocks: 1,
            msg_bytes: 64,
            beam_width: 4,
            queue_penalty_s: 0.0,
            pool_penalty_s: 0.0,
            ..Default::default()
        };
        let swarm = Identity;
        let backend = ChainActivations::new(&swarm, route);
        let mut rng = Rng::new(5);

        let mut last_acc = 0.0;
        for step in 0..60 {
            // class 0: feature 0 positive; class 1: negative
            let mut vals = vec![0f32; b * s * h];
            let mut labels = Vec::with_capacity(b);
            for bi in 0..b {
                let cls = (bi % 2) as usize;
                labels.push(cls);
                let sign = if cls == 0 { 1.0 } else { -1.0 };
                for si in 0..s {
                    vals[(bi * s + si) * h] = sign * (1.0 + rng.f64() as f32 * 0.1);
                }
            }
            let embeds = Tensor::from_f32(&[b, s, h], &vals);
            let rep = tuner
                .train_step(&backend, &embeds, &labels, s - 1)
                .unwrap();
            if step >= 50 {
                last_acc = rep.accuracy;
            }
        }
        assert!(last_acc >= 0.9, "accuracy {last_acc}");
    }
}
