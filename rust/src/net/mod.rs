//! Network substrate: the Petals wire protocol, a length-prefixed framed
//! codec over TCP (real swarms, examples), and helpers shared with the
//! deterministic simulator (which charges time for the same byte counts
//! without moving real bytes).
//!
//! Hidden states travel either raw f32 or compressed with the §3.1
//! dynamic blockwise int8 codec ([`crate::quant`]); the message framing
//! is identical in both cases (`TensorPayload` tags the encoding).

mod codec;
mod framed;

pub use codec::{
    DhtContact, DhtWireRecord, Message, TensorPayload, MAX_DHT_ADDR, MAX_DHT_NODES,
    MAX_DHT_RECORDS, MAX_MIGRATE_CHUNK, MAX_MIGRATE_TOTAL, MAX_PONG_FPS, MAX_RAGGED_ROWS,
};
pub use framed::{read_frame, write_frame, FramedConn};

/// Default TCP port base for local swarms.
pub const BASE_PORT: u16 = 31337;

/// Wire protocol version (see docs/WIRE_PROTOCOL.md for the versioning
/// rules). v2 widened `Pong` with KV-pool occupancy + batch width; v3
/// added the `OpenSessionV3`/`SessionOpenedV3` tags carrying prefix
/// token ids for shared-prefix serving; v4 added the Kademlia RPC tags
/// (`DhtPing`..`DhtStored`, tags 13–20) behind the networked DHT; v5
/// added `InferStepRagged` (tag 21), the per-row `cache_len` step frame
/// behind ragged continuous batching; v6 added the live-migration tags
/// (`MigrateSessionOffer`..`MigrateSessionDone`, tags 22–25) plus
/// `CloseSessionRow` (tag 26) for per-row early exit, and the `moved:`
/// error-string contract for post-migration redirects; v7 added the
/// tracing/telemetry tags (`InferStepTraced`/`StepOutputTraced`/
/// `OpenSessionTraced`, tags 27–29, carrying a 16-byte trace id +
/// span ids + per-stage step timings) and `PingV2`/`PongV2` (tags
/// 30–31, live telemetry + gossiped hot-prefix fingerprints); v8 added
/// `ProposeVerify` (tag 32), the speculative-decoding verify round
/// carrying `m` token positions per row in one frame, plus the
/// implicit-rollback rule: a step frame declaring a cache length below
/// a row's committed length rolls that row back first (rejected draft
/// suffixes free their pages with no extra round trip). Each step
/// appended new tags only, so v7 (and older) frames still decode
/// byte-for-byte; older peers reject the newer tags as undecodable
/// frames, which callers treat as "peer does not speak this version".
/// The codec has no inline negotiation, so mixed-version swarms must
/// not share a model namespace.
pub const PROTOCOL_VERSION: u32 = 8;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tensor::{DType, Tensor};

    #[test]
    fn message_roundtrip_all_variants() {
        let t = Tensor::from_f32(&[2, 64], &vec![0.5f32; 128]);
        let msgs = vec![
            Message::Ping,
            Message::Pong {
                start: 3,
                end: 9,
                throughput: 1.5,
                queue_depth: 2,
                free_pages: 100,
                total_pages: 512,
                batch_width: 8,
            },
            Message::OpenSession { session: 42, batch: 1, prefix_len: 8, max_new: 16 },
            Message::SessionOpened { session: 42 },
            Message::InferStep {
                session: 42,
                cache_len: 7,
                hidden: TensorPayload::raw(&t),
            },
            Message::InferStep {
                session: 42,
                cache_len: 7,
                hidden: TensorPayload::compressed(&t),
            },
            Message::HiddenResult { hidden: TensorPayload::raw(&t) },
            Message::Prefill { session: 7, hidden: TensorPayload::compressed(&t) },
            Message::Forward { hidden: TensorPayload::raw(&t) },
            Message::Backward {
                hidden: TensorPayload::raw(&t),
                grad: TensorPayload::compressed(&t),
            },
            Message::CloseSession { session: 42 },
            Message::Error { message: "boom".into() },
            Message::OpenSessionV3 {
                session: 42,
                batch: 1,
                prefix_len: 8,
                max_new: 16,
                prefill_width: 128,
                prefix_tokens: vec![5, -1, 0, 1 << 30],
            },
            Message::OpenSessionV3 {
                session: 43,
                batch: 1,
                prefix_len: 0,
                max_new: 4,
                prefill_width: 128,
                prefix_tokens: vec![],
            },
            Message::SessionOpenedV3 { session: 42, shared_tokens: 128 },
            Message::InferStepRagged {
                session: 42,
                cache_lens: vec![7, 19, 128],
                hidden: TensorPayload::raw(&t),
            },
            Message::InferStepRagged {
                session: 43,
                cache_lens: vec![1],
                hidden: TensorPayload::compressed(&t),
            },
            Message::InferStepTraced {
                session: 42,
                cache_lens: vec![7, 19],
                trace: crate::trace::TraceContext {
                    trace_id: [7; 16],
                    parent_span: 99,
                },
                hidden: TensorPayload::compressed(&t),
            },
            Message::StepOutputTraced {
                breakdown: crate::trace::StepBreakdown {
                    span_id: 5,
                    queue_us: 10,
                    fuse_us: 20,
                    gather_us: 30,
                    exec_us: 40,
                    commit_us: 50,
                    total_us: 160,
                },
                hidden: TensorPayload::raw(&t),
            },
            Message::OpenSessionTraced {
                session: 44,
                batch: 1,
                prefix_len: 8,
                max_new: 16,
                prefill_width: 128,
                prefix_tokens: vec![5, -1],
                trace: crate::trace::TraceContext {
                    trace_id: [1; 16],
                    parent_span: 2,
                },
            },
            Message::PingV2,
            Message::PongV2 {
                start: 3,
                end: 9,
                throughput: 1.5,
                queue_depth: 2,
                free_pages: 100,
                total_pages: 512,
                batch_width: 8,
                p50_step_us: 1200,
                sessions_active: 4,
                prefix_fps: vec![11, 22, 33],
            },
            Message::ProposeVerify {
                session: 42,
                base_lens: vec![12],
                hidden: TensorPayload::raw(&t),
            },
            Message::ProposeVerify {
                session: 43,
                base_lens: vec![7, 19],
                hidden: TensorPayload::compressed(&t),
            },
        ];
        for m in msgs {
            let bytes = m.encode();
            let back = Message::decode(&bytes).unwrap();
            // compare via re-encoding (Message has no PartialEq on tensors)
            assert_eq!(bytes, back.encode());
        }
    }

    #[test]
    fn payload_raw_vs_compressed_sizes() {
        let t = Tensor::from_f32(&[1, 512], &vec![1.0f32; 512]);
        let raw = TensorPayload::raw(&t);
        let comp = TensorPayload::compressed(&t);
        assert!(comp.wire_len() * 3 < raw.wire_len());
        // decode both back to tensors
        let tr = raw.to_tensor().unwrap();
        let tc = comp.to_tensor().unwrap();
        assert_eq!(tr.shape, t.shape);
        assert_eq!(tc.shape, t.shape);
        assert!(t.max_abs_diff(&tc) <= 1.0 / 127.0 + 1e-6);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Message::decode(&[]).is_none());
        assert!(Message::decode(&[255, 1, 2]).is_none());
        let mut ok = Message::Ping.encode();
        ok.push(0); // trailing junk
        assert!(Message::decode(&ok).is_none());
    }

    #[test]
    fn framed_over_tcp() {
        use std::net::{TcpListener, TcpStream};
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut conn, _) = listener.accept().unwrap();
            let req = read_frame(&mut conn).unwrap();
            let msg = Message::decode(&req).unwrap();
            assert!(matches!(msg, Message::Ping));
            write_frame(
                &mut conn,
                &Message::Pong {
                    start: 0,
                    end: 4,
                    throughput: 9.0,
                    queue_depth: 0,
                    free_pages: 7,
                    total_pages: 9,
                    batch_width: 4,
                }
                .encode(),
            )
            .unwrap();
        });
        let mut client = TcpStream::connect(addr).unwrap();
        write_frame(&mut client, &Message::Ping.encode()).unwrap();
        let resp = Message::decode(&read_frame(&mut client).unwrap()).unwrap();
        match resp {
            Message::Pong { throughput, .. } => assert_eq!(throughput, 9.0),
            other => panic!("unexpected {other:?}"),
        }
        server.join().unwrap();
    }
}
