//! Wire protocol: message types + hand-rolled binary encoding.
//!
//! Layout: `[u8 tag][fields...]`; integers little-endian; tensors as
//! [`TensorPayload`]. The frame length prefix lives one layer down
//! ([`super::framed`]).

use crate::dht::NodeId;
use crate::model::tensor::{DType, Tensor};
use crate::quant::{self, QuantizedTensor};
use crate::trace::{StepBreakdown, TraceContext};

/// Most peers one `DhtNodes` reply may carry (bounds allocation; the
/// Kademlia `K` closest is far below this).
pub const MAX_DHT_NODES: usize = 64;
/// Most records one `DhtValues` reply may carry.
pub const MAX_DHT_RECORDS: usize = 128;
/// Largest DHT record payload (announcement records are < 1 KiB).
pub const MAX_DHT_PAYLOAD: usize = 64 << 10;
/// Longest dialable address string in a [`DhtContact`].
pub const MAX_DHT_ADDR: usize = 256;
/// Most per-row cache lengths one `InferStepRagged` frame may carry
/// (bounds allocation; real batches are far below this).
pub const MAX_RAGGED_ROWS: usize = 4096;
/// Largest data payload one `MigrateSessionChunk` frame may carry
/// (wire v6): snapshots stream in chunks of at most this size so one
/// hostile frame can never force a giant allocation.
pub const MAX_MIGRATE_CHUNK: usize = 4 << 20;
/// Largest *total* serialized session snapshot a migration target will
/// accept across all chunks (wire v6 `MigrateSessionOffer.total_bytes`).
pub const MAX_MIGRATE_TOTAL: usize = 256 << 20;
/// Most hot-prefix fingerprints one `PongV2` may gossip (wire v7;
/// bounds allocation — servers announce at most 8 via the DHT too).
pub const MAX_PONG_FPS: usize = 16;

/// A DHT peer on the wire: node id + the address it can be dialed at.
/// Requests carry the *caller's* contact so the callee can fold the
/// caller into its routing table (Kademlia learns peers from inbound
/// traffic). Clients that are not dialable send an empty address, which
/// callees must not insert.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DhtContact {
    pub id: NodeId,
    pub addr: String,
}

impl DhtContact {
    fn write(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.id.0);
        out.extend_from_slice(&(self.addr.len() as u16).to_le_bytes());
        out.extend_from_slice(self.addr.as_bytes());
    }

    fn read(r: &mut Reader) -> Option<Self> {
        let mut id = [0u8; 32];
        id.copy_from_slice(r.bytes(32)?);
        let n = r.u16()? as usize;
        if n > MAX_DHT_ADDR {
            return None;
        }
        let addr = String::from_utf8(r.bytes(n)?.to_vec()).ok()?;
        Some(DhtContact { id: NodeId(id), addr })
    }
}

/// A TTL record in transit. `ttl_ms` is the *remaining* lifetime at send
/// time: each hop re-stamps `stored_at` against its own clock, so nodes
/// never have to agree on an epoch (only on durations).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DhtWireRecord {
    pub publisher: NodeId,
    pub payload: Vec<u8>,
    pub ttl_ms: u64,
}

impl DhtWireRecord {
    fn write(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.publisher.0);
        out.extend_from_slice(&self.ttl_ms.to_le_bytes());
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.payload);
    }

    fn read(r: &mut Reader) -> Option<Self> {
        let mut id = [0u8; 32];
        id.copy_from_slice(r.bytes(32)?);
        let ttl_ms = r.u64()?;
        let n = r.u32()? as usize;
        if n > MAX_DHT_PAYLOAD {
            return None;
        }
        let payload = r.bytes(n)?.to_vec();
        Some(DhtWireRecord { publisher: NodeId(id), payload, ttl_ms })
    }
}

/// A tensor on the wire: raw f32 or §3.1-compressed.
#[derive(Debug, Clone)]
pub enum TensorPayload {
    Raw(Tensor),
    Compressed(QuantizedTensor),
}

impl TensorPayload {
    pub fn raw(t: &Tensor) -> Self {
        TensorPayload::Raw(t.clone())
    }

    pub fn compressed(t: &Tensor) -> Self {
        TensorPayload::Compressed(quant::quantize(t))
    }

    /// Encode per `compress` flag (the client/server negotiated policy).
    pub fn encode_policy(t: &Tensor, compress: bool) -> Self {
        if compress {
            Self::compressed(t)
        } else {
            Self::raw(t)
        }
    }

    pub fn to_tensor(&self) -> Option<Tensor> {
        match self {
            TensorPayload::Raw(t) => Some(t.clone()),
            TensorPayload::Compressed(q) => Some(quant::dequantize(q)),
        }
    }

    pub fn wire_len(&self) -> usize {
        match self {
            TensorPayload::Raw(t) => 1 + 1 + 4 + t.shape.len() * 4 + t.data.len(),
            TensorPayload::Compressed(q) => 1 + quant::encode(q).len(),
        }
    }

    fn write(&self, out: &mut Vec<u8>) {
        match self {
            TensorPayload::Raw(t) => {
                out.push(0);
                out.push(match t.dtype {
                    DType::F32 => 0,
                    DType::I8 => 1,
                    DType::I32 => 2,
                });
                out.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
                for &d in &t.shape {
                    out.extend_from_slice(&(d as u32).to_le_bytes());
                }
                out.extend_from_slice(&t.data);
            }
            TensorPayload::Compressed(q) => {
                out.push(1);
                out.extend_from_slice(&quant::encode(q));
            }
        }
    }

    fn read(r: &mut Reader) -> Option<Self> {
        match r.u8()? {
            0 => {
                let dtype = match r.u8()? {
                    0 => DType::F32,
                    1 => DType::I8,
                    2 => DType::I32,
                    _ => return None,
                };
                let rank = r.u32()? as usize;
                if rank > 8 {
                    return None;
                }
                let mut shape = Vec::with_capacity(rank);
                for _ in 0..rank {
                    shape.push(r.u32()? as usize);
                }
                let n = shape
                    .iter()
                    .try_fold(dtype.size(), |a, &d| a.checked_mul(d))?;
                let data = r.bytes(n)?.to_vec();
                Some(TensorPayload::Raw(Tensor { shape, dtype, data }))
            }
            1 => {
                let rest = r.rest();
                let q = quant::decode(rest)?;
                let used = quant::encode(&q).len();
                r.advance(used);
                Some(TensorPayload::Compressed(q))
            }
            _ => None,
        }
    }
}

/// Every message of the Petals protocol.
#[derive(Debug, Clone)]
pub enum Message {
    /// Latency probe (client-side routing pings nearby servers, §3.2).
    Ping,
    /// Probe reply: hosted span + self-measured throughput + load +
    /// KV-pool occupancy (`free_pages`/`total_pages`) and the widest
    /// decode batch the server fuses (`batch_width`). Clients use the
    /// pool fields to route around servers that would reject admission.
    Pong {
        start: u32,
        end: u32,
        throughput: f32,
        queue_depth: u32,
        free_pages: u32,
        total_pages: u32,
        batch_width: u32,
    },
    /// Create an inference session with per-session KV cache.
    OpenSession { session: u64, batch: u32, prefix_len: u32, max_new: u32 },
    SessionOpened { session: u64 },
    /// Run the prefix through this server's blocks, filling its caches.
    Prefill { session: u64, hidden: TensorPayload },
    /// One decode step: hidden [B,1,H] in, hidden [B,1,H] out.
    InferStep { session: u64, cache_len: u32, hidden: TensorPayload },
    /// Reply to Prefill / InferStep / Forward / Backward.
    HiddenResult { hidden: TensorPayload },
    /// Stateless parallel forward (fine-tuning & batch inference, §2.2).
    Forward { hidden: TensorPayload },
    /// Backward through frozen blocks: returns grad wrt activations.
    Backward { hidden: TensorPayload, grad: TensorPayload },
    CloseSession { session: u64 },
    Error { message: String },
    /// v3 session open (wire v3): like `OpenSession`, plus the prefix
    /// token ids and the client's prefill width — the identity the
    /// server's prefix cache matches on to attach shared KV pages and
    /// skip recomputing an already-cached prefix. Legacy servers reject
    /// the unknown tag (dropped connection), which clients treat as
    /// retryable and downgrade to the v2 `OpenSession`.
    OpenSessionV3 {
        session: u64,
        batch: u32,
        prefix_len: u32,
        max_new: u32,
        prefill_width: u32,
        prefix_tokens: Vec<i32>,
    },
    /// Reply to `OpenSessionV3`: token positions attached from the
    /// server's prefix cache (0 = cold open, the prefill will run and
    /// register the prefix).
    SessionOpenedV3 { session: u64, shared_tokens: u32 },
    /// Kademlia liveness probe (wire v4). Distinct from [`Message::Ping`]:
    /// DHT traffic runs on a separate listener (`--dht-listen`) and the
    /// reply names the callee so the caller can detect address reuse.
    DhtPing { from: DhtContact },
    /// Reply to `DhtPing`.
    DhtPong { id: NodeId },
    /// `FIND_NODE target` (wire v4): ask for the callee's closest peers.
    DhtFindNode { from: DhtContact, target: NodeId },
    /// Reply to `DhtFindNode` (and taught to the caller's address book).
    DhtNodes { nodes: Vec<DhtContact> },
    /// `FIND_VALUE key` (wire v4).
    DhtFindValue { from: DhtContact, key: NodeId },
    /// Reply to `DhtFindValue`; empty = the callee holds nothing live
    /// under the key (the iterative lookup then widens via `FIND_NODE`).
    DhtValues { found: Vec<DhtWireRecord> },
    /// `STORE key -> record` (wire v4).
    DhtStore { from: DhtContact, key: NodeId, rec: DhtWireRecord },
    /// Reply to `DhtStore`.
    DhtStored,
    /// One RAGGED decode step (wire v5): like [`Message::InferStep`] but
    /// with one cache length PER ROW of the session's batch, so a
    /// multi-prompt session advances rows at different decode depths in
    /// one frame. `cache_lens.len()` must equal the hidden tensor's
    /// leading (batch) dimension. Legacy servers reject the unknown tag
    /// (dropped connection); clients downgrade to per-row `InferStep`
    /// frames only when the rows are uniform.
    InferStepRagged { session: u64, cache_lens: Vec<u32>, hidden: TensorPayload },
    /// Offer a live session's serialized KV state to a peer (wire v6):
    /// a draining server pushes its sessions to the least-loaded peer
    /// covering the same span instead of forcing clients to replay.
    /// `total_bytes` is the full snapshot size (the target rejects
    /// offers past [`MAX_MIGRATE_TOTAL`] or beyond its free pages);
    /// `prefix_fp` is the shared-prefix fingerprint (0 = none) so the
    /// target can re-pin a prefix it already caches instead of storing
    /// a deep copy.
    MigrateSessionOffer { session: u64, total_bytes: u64, prefix_fp: u64 },
    /// Reply to `MigrateSessionOffer`. `accept == 0` declines (the
    /// donor tries the next candidate); `shared_tokens` is how many
    /// prefix tokens the target attached from its own prefix cache
    /// (the donor then skips those pages in the chunk stream).
    MigrateSessionAccept { session: u64, accept: u8, shared_tokens: u32 },
    /// One chunk of the serialized snapshot, ≤ [`MAX_MIGRATE_CHUNK`]
    /// bytes, `seq` strictly increasing from 0. Acked with
    /// `SessionOpened` so the donor detects a dead target mid-stream.
    MigrateSessionChunk { session: u64, seq: u32, data: Vec<u8> },
    /// End of the chunk stream: the target reassembles, decodes, and
    /// restores the session into its own pool, then acks with
    /// `SessionOpened` (or `Error` if the snapshot fails validation).
    MigrateSessionDone { session: u64 },
    /// Close ONE row of a ragged session (wire v6 per-row early exit):
    /// the server frees that row's private KV pages immediately while
    /// the rest of the batch keeps decoding. Acked with
    /// `SessionOpened`. Legacy servers reject the unknown tag (dropped
    /// connection); clients treat that as a no-op — the pages are
    /// reclaimed at session close instead.
    CloseSessionRow { session: u64, row: u32 },
    /// One TRACED ragged decode step (wire v7): [`Message::InferStepRagged`]
    /// plus a trace context (16-byte trace id + parent span id) so the
    /// server can attribute its stage timings to the client's request.
    /// Answered with [`Message::StepOutputTraced`]. Legacy servers
    /// reject the unknown tag (dropped connection); clients downgrade
    /// to the untraced `InferStepRagged` and record the hop with no
    /// breakdown.
    InferStepTraced {
        session: u64,
        cache_lens: Vec<u32>,
        trace: TraceContext,
        hidden: TensorPayload,
    },
    /// Reply to `InferStepTraced`: the hidden result plus where the
    /// server spent the step (queue, fuse, gather, exec, commit —
    /// microseconds, saturating) under a server-minted span id.
    StepOutputTraced { breakdown: StepBreakdown, hidden: TensorPayload },
    /// Traced session open (wire v7): [`Message::OpenSessionV3`] plus
    /// the trace context, so the open itself lands in the server's
    /// request log under the client's trace id. Servers answer with
    /// `SessionOpenedV3` exactly as for V3; legacy servers reject the
    /// unknown tag and clients downgrade to `OpenSessionV3`.
    OpenSessionTraced {
        session: u64,
        batch: u32,
        prefix_len: u32,
        max_new: u32,
        prefill_width: u32,
        prefix_tokens: Vec<i32>,
        trace: TraceContext,
    },
    /// Telemetry probe (wire v7): like [`Message::Ping`] but answered
    /// with [`Message::PongV2`]. Legacy servers reject the unknown tag
    /// (dropped connection); clients fall back to `Ping` per peer.
    PingV2,
    /// Reply to `PingV2`: everything `Pong` carries, plus live
    /// telemetry (p50 step latency, sessions active) and the server's
    /// hot-prefix fingerprints — gossiped here so static-peer-list TCP
    /// swarms get cache-aware sticky routing without a DHT.
    PongV2 {
        start: u32,
        end: u32,
        throughput: f32,
        queue_depth: u32,
        free_pages: u32,
        total_pages: u32,
        batch_width: u32,
        p50_step_us: u32,
        sessions_active: u32,
        prefix_fps: Vec<u64>,
    },
    /// One speculative verify round (wire v8): like
    /// [`Message::InferStepRagged`] but the hidden tensor carries `m`
    /// token positions PER ROW (`[B, m, H]`) — the anchor token plus the
    /// draft candidates — written at cache positions
    /// `base_lens[r] .. base_lens[r] + m - 1` in ONE fused forward.
    /// Answered with [`Message::HiddenResult`] (`[B, m, H]`). A
    /// `base_lens[r]` BELOW the row's committed length first rolls the
    /// row back to it (rejected speculative suffixes free their pages);
    /// the same implicit-rollback rule applies to every step frame, so
    /// no separate rollback round-trip exists. Legacy servers reject
    /// the unknown tag (dropped connection); clients downgrade to `m`
    /// sequential ragged steps, which is bitwise-identical.
    ProposeVerify { session: u64, base_lens: Vec<u32>, hidden: TensorPayload },
}

impl Message {
    /// The variant name — for error replies and logs. Never interpolate
    /// a whole `Message` with `{:?}` into an error string: tensor-
    /// carrying variants Debug-print their payload bytes, turning one
    /// hostile 64 MiB frame into a ~4x larger allocation per reply.
    pub fn kind(&self) -> &'static str {
        match self {
            Message::Ping => "Ping",
            Message::Pong { .. } => "Pong",
            Message::OpenSession { .. } => "OpenSession",
            Message::SessionOpened { .. } => "SessionOpened",
            Message::Prefill { .. } => "Prefill",
            Message::InferStep { .. } => "InferStep",
            Message::HiddenResult { .. } => "HiddenResult",
            Message::Forward { .. } => "Forward",
            Message::Backward { .. } => "Backward",
            Message::CloseSession { .. } => "CloseSession",
            Message::Error { .. } => "Error",
            Message::OpenSessionV3 { .. } => "OpenSessionV3",
            Message::SessionOpenedV3 { .. } => "SessionOpenedV3",
            Message::DhtPing { .. } => "DhtPing",
            Message::DhtPong { .. } => "DhtPong",
            Message::DhtFindNode { .. } => "DhtFindNode",
            Message::DhtNodes { .. } => "DhtNodes",
            Message::DhtFindValue { .. } => "DhtFindValue",
            Message::DhtValues { .. } => "DhtValues",
            Message::DhtStore { .. } => "DhtStore",
            Message::DhtStored => "DhtStored",
            Message::InferStepRagged { .. } => "InferStepRagged",
            Message::MigrateSessionOffer { .. } => "MigrateSessionOffer",
            Message::MigrateSessionAccept { .. } => "MigrateSessionAccept",
            Message::MigrateSessionChunk { .. } => "MigrateSessionChunk",
            Message::MigrateSessionDone { .. } => "MigrateSessionDone",
            Message::CloseSessionRow { .. } => "CloseSessionRow",
            Message::InferStepTraced { .. } => "InferStepTraced",
            Message::StepOutputTraced { .. } => "StepOutputTraced",
            Message::OpenSessionTraced { .. } => "OpenSessionTraced",
            Message::PingV2 => "PingV2",
            Message::PongV2 { .. } => "PongV2",
            Message::ProposeVerify { .. } => "ProposeVerify",
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64);
        match self {
            Message::Ping => out.push(0),
            Message::Pong {
                start,
                end,
                throughput,
                queue_depth,
                free_pages,
                total_pages,
                batch_width,
            } => {
                out.push(1);
                out.extend_from_slice(&start.to_le_bytes());
                out.extend_from_slice(&end.to_le_bytes());
                out.extend_from_slice(&throughput.to_le_bytes());
                out.extend_from_slice(&queue_depth.to_le_bytes());
                out.extend_from_slice(&free_pages.to_le_bytes());
                out.extend_from_slice(&total_pages.to_le_bytes());
                out.extend_from_slice(&batch_width.to_le_bytes());
            }
            Message::OpenSession { session, batch, prefix_len, max_new } => {
                out.push(2);
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&batch.to_le_bytes());
                out.extend_from_slice(&prefix_len.to_le_bytes());
                out.extend_from_slice(&max_new.to_le_bytes());
            }
            Message::SessionOpened { session } => {
                out.push(3);
                out.extend_from_slice(&session.to_le_bytes());
            }
            Message::Prefill { session, hidden } => {
                out.push(4);
                out.extend_from_slice(&session.to_le_bytes());
                hidden.write(&mut out);
            }
            Message::InferStep { session, cache_len, hidden } => {
                out.push(5);
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&cache_len.to_le_bytes());
                hidden.write(&mut out);
            }
            Message::HiddenResult { hidden } => {
                out.push(6);
                hidden.write(&mut out);
            }
            Message::Forward { hidden } => {
                out.push(7);
                hidden.write(&mut out);
            }
            Message::Backward { hidden, grad } => {
                out.push(8);
                hidden.write(&mut out);
                grad.write(&mut out);
            }
            Message::CloseSession { session } => {
                out.push(9);
                out.extend_from_slice(&session.to_le_bytes());
            }
            Message::Error { message } => {
                out.push(10);
                out.extend_from_slice(&(message.len() as u32).to_le_bytes());
                out.extend_from_slice(message.as_bytes());
            }
            Message::OpenSessionV3 {
                session,
                batch,
                prefix_len,
                max_new,
                prefill_width,
                prefix_tokens,
            } => {
                out.push(11);
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&batch.to_le_bytes());
                out.extend_from_slice(&prefix_len.to_le_bytes());
                out.extend_from_slice(&max_new.to_le_bytes());
                out.extend_from_slice(&prefill_width.to_le_bytes());
                out.extend_from_slice(&(prefix_tokens.len() as u32).to_le_bytes());
                for t in prefix_tokens {
                    out.extend_from_slice(&t.to_le_bytes());
                }
            }
            Message::SessionOpenedV3 { session, shared_tokens } => {
                out.push(12);
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&shared_tokens.to_le_bytes());
            }
            Message::DhtPing { from } => {
                out.push(13);
                from.write(&mut out);
            }
            Message::DhtPong { id } => {
                out.push(14);
                out.extend_from_slice(&id.0);
            }
            Message::DhtFindNode { from, target } => {
                out.push(15);
                from.write(&mut out);
                out.extend_from_slice(&target.0);
            }
            Message::DhtNodes { nodes } => {
                out.push(16);
                out.extend_from_slice(&(nodes.len() as u32).to_le_bytes());
                for n in nodes {
                    n.write(&mut out);
                }
            }
            Message::DhtFindValue { from, key } => {
                out.push(17);
                from.write(&mut out);
                out.extend_from_slice(&key.0);
            }
            Message::DhtValues { found } => {
                out.push(18);
                out.extend_from_slice(&(found.len() as u32).to_le_bytes());
                for rec in found {
                    rec.write(&mut out);
                }
            }
            Message::DhtStore { from, key, rec } => {
                out.push(19);
                from.write(&mut out);
                out.extend_from_slice(&key.0);
                rec.write(&mut out);
            }
            Message::DhtStored => out.push(20),
            Message::InferStepRagged { session, cache_lens, hidden } => {
                out.push(21);
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&(cache_lens.len() as u32).to_le_bytes());
                for l in cache_lens {
                    out.extend_from_slice(&l.to_le_bytes());
                }
                hidden.write(&mut out);
            }
            Message::MigrateSessionOffer { session, total_bytes, prefix_fp } => {
                out.push(22);
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&total_bytes.to_le_bytes());
                out.extend_from_slice(&prefix_fp.to_le_bytes());
            }
            Message::MigrateSessionAccept { session, accept, shared_tokens } => {
                out.push(23);
                out.extend_from_slice(&session.to_le_bytes());
                out.push(*accept);
                out.extend_from_slice(&shared_tokens.to_le_bytes());
            }
            Message::MigrateSessionChunk { session, seq, data } => {
                out.push(24);
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&seq.to_le_bytes());
                out.extend_from_slice(&(data.len() as u32).to_le_bytes());
                out.extend_from_slice(data);
            }
            Message::MigrateSessionDone { session } => {
                out.push(25);
                out.extend_from_slice(&session.to_le_bytes());
            }
            Message::CloseSessionRow { session, row } => {
                out.push(26);
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&row.to_le_bytes());
            }
            Message::InferStepTraced { session, cache_lens, trace, hidden } => {
                out.push(27);
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&(cache_lens.len() as u32).to_le_bytes());
                for l in cache_lens {
                    out.extend_from_slice(&l.to_le_bytes());
                }
                out.extend_from_slice(&trace.trace_id);
                out.extend_from_slice(&trace.parent_span.to_le_bytes());
                hidden.write(&mut out);
            }
            Message::StepOutputTraced { breakdown, hidden } => {
                out.push(28);
                out.extend_from_slice(&breakdown.span_id.to_le_bytes());
                out.extend_from_slice(&breakdown.queue_us.to_le_bytes());
                out.extend_from_slice(&breakdown.fuse_us.to_le_bytes());
                out.extend_from_slice(&breakdown.gather_us.to_le_bytes());
                out.extend_from_slice(&breakdown.exec_us.to_le_bytes());
                out.extend_from_slice(&breakdown.commit_us.to_le_bytes());
                out.extend_from_slice(&breakdown.total_us.to_le_bytes());
                hidden.write(&mut out);
            }
            Message::OpenSessionTraced {
                session,
                batch,
                prefix_len,
                max_new,
                prefill_width,
                prefix_tokens,
                trace,
            } => {
                out.push(29);
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&batch.to_le_bytes());
                out.extend_from_slice(&prefix_len.to_le_bytes());
                out.extend_from_slice(&max_new.to_le_bytes());
                out.extend_from_slice(&prefill_width.to_le_bytes());
                out.extend_from_slice(&trace.trace_id);
                out.extend_from_slice(&trace.parent_span.to_le_bytes());
                out.extend_from_slice(&(prefix_tokens.len() as u32).to_le_bytes());
                for t in prefix_tokens {
                    out.extend_from_slice(&t.to_le_bytes());
                }
            }
            Message::PingV2 => out.push(30),
            Message::PongV2 {
                start,
                end,
                throughput,
                queue_depth,
                free_pages,
                total_pages,
                batch_width,
                p50_step_us,
                sessions_active,
                prefix_fps,
            } => {
                out.push(31);
                out.extend_from_slice(&start.to_le_bytes());
                out.extend_from_slice(&end.to_le_bytes());
                out.extend_from_slice(&throughput.to_le_bytes());
                out.extend_from_slice(&queue_depth.to_le_bytes());
                out.extend_from_slice(&free_pages.to_le_bytes());
                out.extend_from_slice(&total_pages.to_le_bytes());
                out.extend_from_slice(&batch_width.to_le_bytes());
                out.extend_from_slice(&p50_step_us.to_le_bytes());
                out.extend_from_slice(&sessions_active.to_le_bytes());
                out.extend_from_slice(&(prefix_fps.len() as u32).to_le_bytes());
                for fp in prefix_fps {
                    out.extend_from_slice(&fp.to_le_bytes());
                }
            }
            Message::ProposeVerify { session, base_lens, hidden } => {
                out.push(32);
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&(base_lens.len() as u32).to_le_bytes());
                for l in base_lens {
                    out.extend_from_slice(&l.to_le_bytes());
                }
                hidden.write(&mut out);
            }
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Option<Message> {
        let mut r = Reader { b: buf, pos: 0 };
        let msg = match r.u8()? {
            0 => Message::Ping,
            1 => Message::Pong {
                start: r.u32()?,
                end: r.u32()?,
                throughput: r.f32()?,
                queue_depth: r.u32()?,
                free_pages: r.u32()?,
                total_pages: r.u32()?,
                batch_width: r.u32()?,
            },
            2 => Message::OpenSession {
                session: r.u64()?,
                batch: r.u32()?,
                prefix_len: r.u32()?,
                max_new: r.u32()?,
            },
            3 => Message::SessionOpened { session: r.u64()? },
            4 => Message::Prefill { session: r.u64()?, hidden: TensorPayload::read(&mut r)? },
            5 => Message::InferStep {
                session: r.u64()?,
                cache_len: r.u32()?,
                hidden: TensorPayload::read(&mut r)?,
            },
            6 => Message::HiddenResult { hidden: TensorPayload::read(&mut r)? },
            7 => Message::Forward { hidden: TensorPayload::read(&mut r)? },
            8 => Message::Backward {
                hidden: TensorPayload::read(&mut r)?,
                grad: TensorPayload::read(&mut r)?,
            },
            9 => Message::CloseSession { session: r.u64()? },
            10 => {
                let n = r.u32()? as usize;
                let bytes = r.bytes(n)?;
                Message::Error { message: String::from_utf8(bytes.to_vec()).ok()? }
            }
            11 => {
                let session = r.u64()?;
                let batch = r.u32()?;
                let prefix_len = r.u32()?;
                let max_new = r.u32()?;
                let prefill_width = r.u32()?;
                let n = r.u32()? as usize;
                if n > 1 << 20 {
                    return None; // bound allocation on hostile input
                }
                let mut prefix_tokens = Vec::with_capacity(n);
                for _ in 0..n {
                    prefix_tokens.push(r.u32()? as i32);
                }
                Message::OpenSessionV3 {
                    session,
                    batch,
                    prefix_len,
                    max_new,
                    prefill_width,
                    prefix_tokens,
                }
            }
            12 => Message::SessionOpenedV3 { session: r.u64()?, shared_tokens: r.u32()? },
            13 => Message::DhtPing { from: DhtContact::read(&mut r)? },
            14 => {
                let mut id = [0u8; 32];
                id.copy_from_slice(r.bytes(32)?);
                Message::DhtPong { id: NodeId(id) }
            }
            15 => {
                let from = DhtContact::read(&mut r)?;
                let mut t = [0u8; 32];
                t.copy_from_slice(r.bytes(32)?);
                Message::DhtFindNode { from, target: NodeId(t) }
            }
            16 => {
                let n = r.u32()? as usize;
                if n > MAX_DHT_NODES {
                    return None;
                }
                let mut nodes = Vec::with_capacity(n);
                for _ in 0..n {
                    nodes.push(DhtContact::read(&mut r)?);
                }
                Message::DhtNodes { nodes }
            }
            17 => {
                let from = DhtContact::read(&mut r)?;
                let mut k = [0u8; 32];
                k.copy_from_slice(r.bytes(32)?);
                Message::DhtFindValue { from, key: NodeId(k) }
            }
            18 => {
                let n = r.u32()? as usize;
                if n > MAX_DHT_RECORDS {
                    return None;
                }
                let mut found = Vec::with_capacity(n);
                for _ in 0..n {
                    found.push(DhtWireRecord::read(&mut r)?);
                }
                Message::DhtValues { found }
            }
            19 => {
                let from = DhtContact::read(&mut r)?;
                let mut k = [0u8; 32];
                k.copy_from_slice(r.bytes(32)?);
                let rec = DhtWireRecord::read(&mut r)?;
                Message::DhtStore { from, key: NodeId(k), rec }
            }
            20 => Message::DhtStored,
            21 => {
                let session = r.u64()?;
                let n = r.u32()? as usize;
                if n > MAX_RAGGED_ROWS {
                    return None; // bound allocation on hostile input
                }
                let mut cache_lens = Vec::with_capacity(n);
                for _ in 0..n {
                    cache_lens.push(r.u32()?);
                }
                Message::InferStepRagged {
                    session,
                    cache_lens,
                    hidden: TensorPayload::read(&mut r)?,
                }
            }
            22 => Message::MigrateSessionOffer {
                session: r.u64()?,
                total_bytes: r.u64()?,
                prefix_fp: r.u64()?,
            },
            23 => Message::MigrateSessionAccept {
                session: r.u64()?,
                accept: r.u8()?,
                shared_tokens: r.u32()?,
            },
            24 => {
                let session = r.u64()?;
                let seq = r.u32()?;
                let n = r.u32()? as usize;
                if n > MAX_MIGRATE_CHUNK {
                    return None; // bound allocation on hostile input
                }
                let data = r.bytes(n)?.to_vec();
                Message::MigrateSessionChunk { session, seq, data }
            }
            25 => Message::MigrateSessionDone { session: r.u64()? },
            26 => Message::CloseSessionRow { session: r.u64()?, row: r.u32()? },
            27 => {
                let session = r.u64()?;
                let n = r.u32()? as usize;
                if n > MAX_RAGGED_ROWS {
                    return None; // bound allocation on hostile input
                }
                let mut cache_lens = Vec::with_capacity(n);
                for _ in 0..n {
                    cache_lens.push(r.u32()?);
                }
                let mut trace_id = [0u8; 16];
                trace_id.copy_from_slice(r.bytes(16)?);
                let parent_span = r.u64()?;
                Message::InferStepTraced {
                    session,
                    cache_lens,
                    trace: TraceContext { trace_id, parent_span },
                    hidden: TensorPayload::read(&mut r)?,
                }
            }
            28 => Message::StepOutputTraced {
                breakdown: StepBreakdown {
                    span_id: r.u64()?,
                    queue_us: r.u32()?,
                    fuse_us: r.u32()?,
                    gather_us: r.u32()?,
                    exec_us: r.u32()?,
                    commit_us: r.u32()?,
                    total_us: r.u32()?,
                },
                hidden: TensorPayload::read(&mut r)?,
            },
            29 => {
                let session = r.u64()?;
                let batch = r.u32()?;
                let prefix_len = r.u32()?;
                let max_new = r.u32()?;
                let prefill_width = r.u32()?;
                let mut trace_id = [0u8; 16];
                trace_id.copy_from_slice(r.bytes(16)?);
                let parent_span = r.u64()?;
                let n = r.u32()? as usize;
                if n > 1 << 20 {
                    return None; // bound allocation on hostile input
                }
                let mut prefix_tokens = Vec::with_capacity(n);
                for _ in 0..n {
                    prefix_tokens.push(r.u32()? as i32);
                }
                Message::OpenSessionTraced {
                    session,
                    batch,
                    prefix_len,
                    max_new,
                    prefill_width,
                    prefix_tokens,
                    trace: TraceContext { trace_id, parent_span },
                }
            }
            30 => Message::PingV2,
            31 => {
                let start = r.u32()?;
                let end = r.u32()?;
                let throughput = r.f32()?;
                let queue_depth = r.u32()?;
                let free_pages = r.u32()?;
                let total_pages = r.u32()?;
                let batch_width = r.u32()?;
                let p50_step_us = r.u32()?;
                let sessions_active = r.u32()?;
                let n = r.u32()? as usize;
                if n > MAX_PONG_FPS {
                    return None; // bound allocation on hostile input
                }
                let mut prefix_fps = Vec::with_capacity(n);
                for _ in 0..n {
                    prefix_fps.push(r.u64()?);
                }
                Message::PongV2 {
                    start,
                    end,
                    throughput,
                    queue_depth,
                    free_pages,
                    total_pages,
                    batch_width,
                    p50_step_us,
                    sessions_active,
                    prefix_fps,
                }
            }
            32 => {
                let session = r.u64()?;
                let n = r.u32()? as usize;
                if n > MAX_RAGGED_ROWS {
                    return None; // bound allocation on hostile input
                }
                let mut base_lens = Vec::with_capacity(n);
                for _ in 0..n {
                    base_lens.push(r.u32()?);
                }
                Message::ProposeVerify {
                    session,
                    base_lens,
                    hidden: TensorPayload::read(&mut r)?,
                }
            }
            _ => return None,
        };
        if r.pos != buf.len() {
            return None; // trailing junk => corrupt frame
        }
        Some(msg)
    }
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn u8(&mut self) -> Option<u8> {
        let v = *self.b.get(self.pos)?;
        self.pos += 1;
        Some(v)
    }

    fn u16(&mut self) -> Option<u16> {
        let v = u16::from_le_bytes(self.b.get(self.pos..self.pos + 2)?.try_into().ok()?);
        self.pos += 2;
        Some(v)
    }

    fn u32(&mut self) -> Option<u32> {
        let v = u32::from_le_bytes(self.b.get(self.pos..self.pos + 4)?.try_into().ok()?);
        self.pos += 4;
        Some(v)
    }

    fn u64(&mut self) -> Option<u64> {
        let v = u64::from_le_bytes(self.b.get(self.pos..self.pos + 8)?.try_into().ok()?);
        self.pos += 8;
        Some(v)
    }

    fn f32(&mut self) -> Option<f32> {
        let v = f32::from_le_bytes(self.b.get(self.pos..self.pos + 4)?.try_into().ok()?);
        self.pos += 4;
        Some(v)
    }

    fn bytes(&mut self, n: usize) -> Option<&'a [u8]> {
        let v = self.b.get(self.pos..self.pos + n)?;
        self.pos += n;
        Some(v)
    }

    fn rest(&self) -> &'a [u8] {
        &self.b[self.pos..]
    }

    fn advance(&mut self, n: usize) {
        self.pos += n;
    }
}

#[cfg(test)]
mod tests {
    //! DHT-frame coverage lives here next to the codec; the cross-tag
    //! round-trips for the inference messages are in `net/mod.rs`.
    use super::*;

    fn contact(name: &str, addr: &str) -> DhtContact {
        DhtContact { id: NodeId::from_name(name), addr: addr.to_string() }
    }

    fn dht_messages() -> Vec<Message> {
        vec![
            Message::DhtPing { from: contact("a", "127.0.0.1:4100") },
            Message::DhtPing { from: contact("client", "") }, // undialable caller
            Message::DhtPong { id: NodeId::from_name("b") },
            Message::DhtFindNode {
                from: contact("a", "127.0.0.1:4100"),
                target: NodeId::from_name("t"),
            },
            Message::DhtNodes { nodes: vec![] },
            Message::DhtNodes {
                nodes: (0..8).map(|i| contact(&format!("n{i}"), &format!("10.0.0.{i}:31337"))).collect(),
            },
            Message::DhtFindValue {
                from: contact("a", "127.0.0.1:4100"),
                key: NodeId::from_name("bloom/block/3"),
            },
            Message::DhtValues { found: vec![] },
            Message::DhtValues {
                found: vec![
                    DhtWireRecord {
                        publisher: NodeId::from_name("s1"),
                        payload: vec![1, 2, 3],
                        ttl_ms: 30_000,
                    },
                    DhtWireRecord {
                        publisher: NodeId::from_name("s2"),
                        payload: vec![],
                        ttl_ms: 1,
                    },
                ],
            },
            Message::DhtStore {
                from: contact("a", "127.0.0.1:4100"),
                key: NodeId::from_name("bloom/block/0"),
                rec: DhtWireRecord {
                    publisher: NodeId::from_name("s1"),
                    payload: b"announcement".to_vec(),
                    ttl_ms: 30_000,
                },
            },
            Message::DhtStored,
        ]
    }

    #[test]
    fn dht_messages_roundtrip() {
        for m in dht_messages() {
            let bytes = m.encode();
            let back = Message::decode(&bytes).expect("decode");
            assert_eq!(bytes, back.encode(), "{m:?}");
        }
    }

    /// Fuzz-ish robustness: every truncation of every DHT frame must
    /// decode as `None` (a legacy-compatible protocol error — the same
    /// signal an unknown tag produces), never panic, and never alias to
    /// a different valid message.
    #[test]
    fn truncated_dht_frames_rejected() {
        for m in dht_messages() {
            let bytes = m.encode();
            for cut in 0..bytes.len() {
                assert!(
                    Message::decode(&bytes[..cut]).is_none(),
                    "truncated {m:?} at {cut} decoded"
                );
            }
        }
    }

    /// Corrupt tag bytes: unknown tags (the signal a v3 peer sees for
    /// every v4 frame) and cross-tag payloads must reject cleanly.
    #[test]
    fn unknown_and_swapped_tags_rejected() {
        // all unknown tags reject on a representative payload (33 is the
        // first unassigned tag after wire v8's ProposeVerify)
        let body = Message::DhtPing { from: contact("a", "127.0.0.1:1") }.encode();
        for tag in 33..=255u8 {
            let mut b = body.clone();
            b[0] = tag;
            assert!(Message::decode(&b).is_none(), "tag {tag} accepted");
        }
        // a frame shown to a decoder as each *known* tag must not
        // panic (it may legitimately alias for container-free tags)
        for m in dht_messages() {
            let bytes = m.encode();
            for tag in 0..=32u8 {
                let mut b = bytes.clone();
                b[0] = tag;
                let _ = Message::decode(&b); // no panic is the assertion
            }
        }
    }

    /// Hostile counts/lengths: a forged node/record count or an oversized
    /// payload length must be rejected before allocation.
    #[test]
    fn hostile_counts_bounded() {
        let mut b = vec![16u8]; // DhtNodes
        b.extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(Message::decode(&b).is_none());
        let mut b = vec![18u8]; // DhtValues
        b.extend_from_slice(&((MAX_DHT_RECORDS as u32) + 1).to_le_bytes());
        assert!(Message::decode(&b).is_none());
        // record with a payload length far past the frame end
        let mut b = vec![18u8];
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&[7u8; 32]); // publisher
        b.extend_from_slice(&1000u64.to_le_bytes()); // ttl
        b.extend_from_slice(&((MAX_DHT_PAYLOAD as u32) + 1).to_le_bytes());
        assert!(Message::decode(&b).is_none());
        // contact with an oversized address length
        let mut b = vec![13u8];
        b.extend_from_slice(&[1u8; 32]);
        b.extend_from_slice(&u16::MAX.to_le_bytes());
        assert!(Message::decode(&b).is_none());
    }

    /// Trailing junk after a complete DHT message is a corrupt frame.
    #[test]
    fn trailing_bytes_rejected() {
        let mut b = Message::DhtStored.encode();
        b.push(0);
        assert!(Message::decode(&b).is_none());
    }

    fn migrate_messages() -> Vec<Message> {
        vec![
            Message::MigrateSessionOffer {
                session: 0xDEAD_BEEF,
                total_bytes: 1 << 20,
                prefix_fp: 0x1234_5678_9ABC_DEF0,
            },
            Message::MigrateSessionOffer { session: 1, total_bytes: 0, prefix_fp: 0 },
            Message::MigrateSessionAccept { session: 7, accept: 1, shared_tokens: 16 },
            Message::MigrateSessionAccept { session: 7, accept: 0, shared_tokens: 0 },
            Message::MigrateSessionChunk { session: 7, seq: 0, data: vec![1, 2, 3, 4] },
            Message::MigrateSessionChunk { session: 7, seq: 3, data: vec![] },
            Message::MigrateSessionDone { session: 7 },
            Message::CloseSessionRow { session: 7, row: 2 },
        ]
    }

    /// Wire-v6 migration frames round-trip byte-exact.
    #[test]
    fn migrate_messages_roundtrip() {
        for m in migrate_messages() {
            let bytes = m.encode();
            let back = Message::decode(&bytes).expect("decode");
            assert_eq!(bytes, back.encode(), "{}", m.kind());
        }
    }

    /// Every truncation of every migration frame rejects cleanly — the
    /// same hardening bar tags 13–21 meet.
    #[test]
    fn truncated_migrate_frames_rejected() {
        for m in migrate_messages() {
            let bytes = m.encode();
            for cut in 0..bytes.len() {
                assert!(
                    Message::decode(&bytes[..cut]).is_none(),
                    "truncated {} at {cut} decoded",
                    m.kind()
                );
            }
        }
    }

    /// A forged chunk length past [`MAX_MIGRATE_CHUNK`] (or past the
    /// frame end) must be rejected before allocation; trailing junk
    /// after a complete migration frame is a corrupt frame.
    #[test]
    fn hostile_migrate_frames_rejected() {
        // chunk length > cap
        let mut b = vec![24u8];
        b.extend_from_slice(&7u64.to_le_bytes());
        b.extend_from_slice(&0u32.to_le_bytes());
        b.extend_from_slice(&((MAX_MIGRATE_CHUNK as u32) + 1).to_le_bytes());
        assert!(Message::decode(&b).is_none());
        // chunk length within cap but past the frame end
        let mut b = vec![24u8];
        b.extend_from_slice(&7u64.to_le_bytes());
        b.extend_from_slice(&0u32.to_le_bytes());
        b.extend_from_slice(&1024u32.to_le_bytes());
        b.extend_from_slice(&[0u8; 16]);
        assert!(Message::decode(&b).is_none());
        // trailing junk
        let mut b = Message::MigrateSessionDone { session: 7 }.encode();
        b.push(0);
        assert!(Message::decode(&b).is_none());
        let mut b = Message::CloseSessionRow { session: 7, row: 0 }.encode();
        b.push(9);
        assert!(Message::decode(&b).is_none());
    }

    fn traced_messages() -> Vec<Message> {
        use crate::model::tensor::Tensor;
        let ctx = TraceContext { trace_id: [0xA5; 16], parent_span: 0x1122_3344_5566_7788 };
        let t = Tensor::zeros(&[2, 1, 4], DType::F32);
        vec![
            Message::InferStepTraced {
                session: 7,
                cache_lens: vec![3, 9],
                trace: ctx,
                hidden: TensorPayload::raw(&t),
            },
            Message::InferStepTraced {
                session: 7,
                cache_lens: vec![],
                trace: ctx,
                hidden: TensorPayload::raw(&t),
            },
            Message::StepOutputTraced {
                breakdown: StepBreakdown {
                    span_id: 42,
                    queue_us: 1,
                    fuse_us: 2,
                    gather_us: 3,
                    exec_us: 4,
                    commit_us: 5,
                    total_us: 20,
                },
                hidden: TensorPayload::raw(&t),
            },
            Message::OpenSessionTraced {
                session: 7,
                batch: 2,
                prefix_len: 5,
                max_new: 16,
                prefill_width: 2,
                prefix_tokens: vec![1, -2, 3],
                trace: ctx,
            },
            Message::OpenSessionTraced {
                session: 8,
                batch: 1,
                prefix_len: 0,
                max_new: 1,
                prefill_width: 1,
                prefix_tokens: vec![],
                trace: ctx,
            },
            Message::PingV2,
            Message::PongV2 {
                start: 0,
                end: 4,
                throughput: 3.5,
                queue_depth: 2,
                free_pages: 10,
                total_pages: 64,
                batch_width: 8,
                p50_step_us: 900,
                sessions_active: 3,
                prefix_fps: vec![0xDEAD, 0xBEEF],
            },
            Message::PongV2 {
                start: 1,
                end: 2,
                throughput: 0.0,
                queue_depth: 0,
                free_pages: 0,
                total_pages: 0,
                batch_width: 1,
                p50_step_us: 0,
                sessions_active: 0,
                prefix_fps: vec![],
            },
        ]
    }

    /// Wire-v7 tracing/telemetry frames round-trip byte-exact.
    #[test]
    fn traced_messages_roundtrip() {
        for m in traced_messages() {
            let bytes = m.encode();
            let back = Message::decode(&bytes).expect("decode");
            assert_eq!(bytes, back.encode(), "{}", m.kind());
        }
    }

    /// Every truncation of every v7 frame rejects cleanly — the same
    /// hardening bar every prior tag meets.
    #[test]
    fn truncated_traced_frames_rejected() {
        for m in traced_messages() {
            let bytes = m.encode();
            for cut in 0..bytes.len() {
                assert!(
                    Message::decode(&bytes[..cut]).is_none(),
                    "truncated {} at {cut} decoded",
                    m.kind()
                );
            }
        }
    }

    /// Forged counts on the v7 container frames must be rejected before
    /// allocation; trailing junk after a complete frame is corrupt.
    #[test]
    fn hostile_traced_frames_rejected() {
        // InferStepTraced row count > cap
        let mut b = vec![27u8];
        b.extend_from_slice(&7u64.to_le_bytes());
        b.extend_from_slice(&((MAX_RAGGED_ROWS as u32) + 1).to_le_bytes());
        assert!(Message::decode(&b).is_none());
        // PongV2 fingerprint count > cap
        let mut b = vec![31u8];
        b.extend_from_slice(&[0u8; 36]); // fixed fields
        b.extend_from_slice(&((MAX_PONG_FPS as u32) + 1).to_le_bytes());
        assert!(Message::decode(&b).is_none());
        // OpenSessionTraced token count > cap
        let mut b = vec![29u8];
        b.extend_from_slice(&[0u8; 24]); // session + 4 u32s
        b.extend_from_slice(&[0u8; 24]); // trace id + parent span
        b.extend_from_slice(&((1u32 << 20) + 1).to_le_bytes());
        assert!(Message::decode(&b).is_none());
        // trailing junk
        let mut b = Message::PingV2.encode();
        b.push(0);
        assert!(Message::decode(&b).is_none());
    }

    fn spec_messages() -> Vec<Message> {
        use crate::model::tensor::Tensor;
        let t = Tensor::zeros(&[1, 4, 8], DType::F32);
        let wide = Tensor::zeros(&[2, 3, 8], DType::F32);
        vec![
            Message::ProposeVerify {
                session: 7,
                base_lens: vec![12],
                hidden: TensorPayload::raw(&t),
            },
            Message::ProposeVerify {
                session: 0xFEED_FACE,
                base_lens: vec![3, 9],
                hidden: TensorPayload::raw(&wide),
            },
            Message::ProposeVerify {
                session: 1,
                base_lens: vec![],
                hidden: TensorPayload::raw(&t),
            },
        ]
    }

    /// Wire-v8 speculative frames round-trip byte-exact.
    #[test]
    fn spec_messages_roundtrip() {
        for m in spec_messages() {
            let bytes = m.encode();
            let back = Message::decode(&bytes).expect("decode");
            assert_eq!(bytes, back.encode(), "{}", m.kind());
        }
    }

    /// Every truncation of every v8 frame rejects cleanly — the same
    /// hardening bar every prior tag meets.
    #[test]
    fn truncated_spec_frames_rejected() {
        for m in spec_messages() {
            let bytes = m.encode();
            for cut in 0..bytes.len() {
                assert!(
                    Message::decode(&bytes[..cut]).is_none(),
                    "truncated {} at {cut} decoded",
                    m.kind()
                );
            }
        }
    }

    /// A forged row count on `ProposeVerify` must be rejected before
    /// allocation; trailing junk after a complete frame is corrupt.
    #[test]
    fn hostile_spec_frames_rejected() {
        // row count > cap
        let mut b = vec![32u8];
        b.extend_from_slice(&7u64.to_le_bytes());
        b.extend_from_slice(&((MAX_RAGGED_ROWS as u32) + 1).to_le_bytes());
        assert!(Message::decode(&b).is_none());
        // trailing junk
        let mut b = spec_messages()[0].encode();
        b.push(0);
        assert!(Message::decode(&b).is_none());
    }
}
