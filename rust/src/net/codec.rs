//! Wire protocol: message types + hand-rolled binary encoding.
//!
//! Layout: `[u8 tag][fields...]`; integers little-endian; tensors as
//! [`TensorPayload`]. The frame length prefix lives one layer down
//! ([`super::framed`]).

use crate::model::tensor::{DType, Tensor};
use crate::quant::{self, QuantizedTensor};

/// A tensor on the wire: raw f32 or §3.1-compressed.
#[derive(Debug, Clone)]
pub enum TensorPayload {
    Raw(Tensor),
    Compressed(QuantizedTensor),
}

impl TensorPayload {
    pub fn raw(t: &Tensor) -> Self {
        TensorPayload::Raw(t.clone())
    }

    pub fn compressed(t: &Tensor) -> Self {
        TensorPayload::Compressed(quant::quantize(t))
    }

    /// Encode per `compress` flag (the client/server negotiated policy).
    pub fn encode_policy(t: &Tensor, compress: bool) -> Self {
        if compress {
            Self::compressed(t)
        } else {
            Self::raw(t)
        }
    }

    pub fn to_tensor(&self) -> Option<Tensor> {
        match self {
            TensorPayload::Raw(t) => Some(t.clone()),
            TensorPayload::Compressed(q) => Some(quant::dequantize(q)),
        }
    }

    pub fn wire_len(&self) -> usize {
        match self {
            TensorPayload::Raw(t) => 1 + 1 + 4 + t.shape.len() * 4 + t.data.len(),
            TensorPayload::Compressed(q) => 1 + quant::encode(q).len(),
        }
    }

    fn write(&self, out: &mut Vec<u8>) {
        match self {
            TensorPayload::Raw(t) => {
                out.push(0);
                out.push(match t.dtype {
                    DType::F32 => 0,
                    DType::I8 => 1,
                    DType::I32 => 2,
                });
                out.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
                for &d in &t.shape {
                    out.extend_from_slice(&(d as u32).to_le_bytes());
                }
                out.extend_from_slice(&t.data);
            }
            TensorPayload::Compressed(q) => {
                out.push(1);
                out.extend_from_slice(&quant::encode(q));
            }
        }
    }

    fn read(r: &mut Reader) -> Option<Self> {
        match r.u8()? {
            0 => {
                let dtype = match r.u8()? {
                    0 => DType::F32,
                    1 => DType::I8,
                    2 => DType::I32,
                    _ => return None,
                };
                let rank = r.u32()? as usize;
                if rank > 8 {
                    return None;
                }
                let mut shape = Vec::with_capacity(rank);
                for _ in 0..rank {
                    shape.push(r.u32()? as usize);
                }
                let n: usize = shape.iter().product::<usize>() * dtype.size();
                let data = r.bytes(n)?.to_vec();
                Some(TensorPayload::Raw(Tensor { shape, dtype, data }))
            }
            1 => {
                let rest = r.rest();
                let q = quant::decode(rest)?;
                let used = quant::encode(&q).len();
                r.advance(used);
                Some(TensorPayload::Compressed(q))
            }
            _ => None,
        }
    }
}

/// Every message of the Petals protocol.
#[derive(Debug, Clone)]
pub enum Message {
    /// Latency probe (client-side routing pings nearby servers, §3.2).
    Ping,
    /// Probe reply: hosted span + self-measured throughput + load +
    /// KV-pool occupancy (`free_pages`/`total_pages`) and the widest
    /// decode batch the server fuses (`batch_width`). Clients use the
    /// pool fields to route around servers that would reject admission.
    Pong {
        start: u32,
        end: u32,
        throughput: f32,
        queue_depth: u32,
        free_pages: u32,
        total_pages: u32,
        batch_width: u32,
    },
    /// Create an inference session with per-session KV cache.
    OpenSession { session: u64, batch: u32, prefix_len: u32, max_new: u32 },
    SessionOpened { session: u64 },
    /// Run the prefix through this server's blocks, filling its caches.
    Prefill { session: u64, hidden: TensorPayload },
    /// One decode step: hidden [B,1,H] in, hidden [B,1,H] out.
    InferStep { session: u64, cache_len: u32, hidden: TensorPayload },
    /// Reply to Prefill / InferStep / Forward / Backward.
    HiddenResult { hidden: TensorPayload },
    /// Stateless parallel forward (fine-tuning & batch inference, §2.2).
    Forward { hidden: TensorPayload },
    /// Backward through frozen blocks: returns grad wrt activations.
    Backward { hidden: TensorPayload, grad: TensorPayload },
    CloseSession { session: u64 },
    Error { message: String },
    /// v3 session open (wire v3): like `OpenSession`, plus the prefix
    /// token ids and the client's prefill width — the identity the
    /// server's prefix cache matches on to attach shared KV pages and
    /// skip recomputing an already-cached prefix. Legacy servers reject
    /// the unknown tag (dropped connection), which clients treat as
    /// retryable and downgrade to the v2 `OpenSession`.
    OpenSessionV3 {
        session: u64,
        batch: u32,
        prefix_len: u32,
        max_new: u32,
        prefill_width: u32,
        prefix_tokens: Vec<i32>,
    },
    /// Reply to `OpenSessionV3`: token positions attached from the
    /// server's prefix cache (0 = cold open, the prefill will run and
    /// register the prefix).
    SessionOpenedV3 { session: u64, shared_tokens: u32 },
}

impl Message {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64);
        match self {
            Message::Ping => out.push(0),
            Message::Pong {
                start,
                end,
                throughput,
                queue_depth,
                free_pages,
                total_pages,
                batch_width,
            } => {
                out.push(1);
                out.extend_from_slice(&start.to_le_bytes());
                out.extend_from_slice(&end.to_le_bytes());
                out.extend_from_slice(&throughput.to_le_bytes());
                out.extend_from_slice(&queue_depth.to_le_bytes());
                out.extend_from_slice(&free_pages.to_le_bytes());
                out.extend_from_slice(&total_pages.to_le_bytes());
                out.extend_from_slice(&batch_width.to_le_bytes());
            }
            Message::OpenSession { session, batch, prefix_len, max_new } => {
                out.push(2);
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&batch.to_le_bytes());
                out.extend_from_slice(&prefix_len.to_le_bytes());
                out.extend_from_slice(&max_new.to_le_bytes());
            }
            Message::SessionOpened { session } => {
                out.push(3);
                out.extend_from_slice(&session.to_le_bytes());
            }
            Message::Prefill { session, hidden } => {
                out.push(4);
                out.extend_from_slice(&session.to_le_bytes());
                hidden.write(&mut out);
            }
            Message::InferStep { session, cache_len, hidden } => {
                out.push(5);
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&cache_len.to_le_bytes());
                hidden.write(&mut out);
            }
            Message::HiddenResult { hidden } => {
                out.push(6);
                hidden.write(&mut out);
            }
            Message::Forward { hidden } => {
                out.push(7);
                hidden.write(&mut out);
            }
            Message::Backward { hidden, grad } => {
                out.push(8);
                hidden.write(&mut out);
                grad.write(&mut out);
            }
            Message::CloseSession { session } => {
                out.push(9);
                out.extend_from_slice(&session.to_le_bytes());
            }
            Message::Error { message } => {
                out.push(10);
                out.extend_from_slice(&(message.len() as u32).to_le_bytes());
                out.extend_from_slice(message.as_bytes());
            }
            Message::OpenSessionV3 {
                session,
                batch,
                prefix_len,
                max_new,
                prefill_width,
                prefix_tokens,
            } => {
                out.push(11);
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&batch.to_le_bytes());
                out.extend_from_slice(&prefix_len.to_le_bytes());
                out.extend_from_slice(&max_new.to_le_bytes());
                out.extend_from_slice(&prefill_width.to_le_bytes());
                out.extend_from_slice(&(prefix_tokens.len() as u32).to_le_bytes());
                for t in prefix_tokens {
                    out.extend_from_slice(&t.to_le_bytes());
                }
            }
            Message::SessionOpenedV3 { session, shared_tokens } => {
                out.push(12);
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&shared_tokens.to_le_bytes());
            }
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Option<Message> {
        let mut r = Reader { b: buf, pos: 0 };
        let msg = match r.u8()? {
            0 => Message::Ping,
            1 => Message::Pong {
                start: r.u32()?,
                end: r.u32()?,
                throughput: r.f32()?,
                queue_depth: r.u32()?,
                free_pages: r.u32()?,
                total_pages: r.u32()?,
                batch_width: r.u32()?,
            },
            2 => Message::OpenSession {
                session: r.u64()?,
                batch: r.u32()?,
                prefix_len: r.u32()?,
                max_new: r.u32()?,
            },
            3 => Message::SessionOpened { session: r.u64()? },
            4 => Message::Prefill { session: r.u64()?, hidden: TensorPayload::read(&mut r)? },
            5 => Message::InferStep {
                session: r.u64()?,
                cache_len: r.u32()?,
                hidden: TensorPayload::read(&mut r)?,
            },
            6 => Message::HiddenResult { hidden: TensorPayload::read(&mut r)? },
            7 => Message::Forward { hidden: TensorPayload::read(&mut r)? },
            8 => Message::Backward {
                hidden: TensorPayload::read(&mut r)?,
                grad: TensorPayload::read(&mut r)?,
            },
            9 => Message::CloseSession { session: r.u64()? },
            10 => {
                let n = r.u32()? as usize;
                let bytes = r.bytes(n)?;
                Message::Error { message: String::from_utf8(bytes.to_vec()).ok()? }
            }
            11 => {
                let session = r.u64()?;
                let batch = r.u32()?;
                let prefix_len = r.u32()?;
                let max_new = r.u32()?;
                let prefill_width = r.u32()?;
                let n = r.u32()? as usize;
                if n > 1 << 20 {
                    return None; // bound allocation on hostile input
                }
                let mut prefix_tokens = Vec::with_capacity(n);
                for _ in 0..n {
                    prefix_tokens.push(r.u32()? as i32);
                }
                Message::OpenSessionV3 {
                    session,
                    batch,
                    prefix_len,
                    max_new,
                    prefill_width,
                    prefix_tokens,
                }
            }
            12 => Message::SessionOpenedV3 { session: r.u64()?, shared_tokens: r.u32()? },
            _ => return None,
        };
        if r.pos != buf.len() {
            return None; // trailing junk => corrupt frame
        }
        Some(msg)
    }
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn u8(&mut self) -> Option<u8> {
        let v = *self.b.get(self.pos)?;
        self.pos += 1;
        Some(v)
    }

    fn u32(&mut self) -> Option<u32> {
        let v = u32::from_le_bytes(self.b.get(self.pos..self.pos + 4)?.try_into().ok()?);
        self.pos += 4;
        Some(v)
    }

    fn u64(&mut self) -> Option<u64> {
        let v = u64::from_le_bytes(self.b.get(self.pos..self.pos + 8)?.try_into().ok()?);
        self.pos += 8;
        Some(v)
    }

    fn f32(&mut self) -> Option<f32> {
        let v = f32::from_le_bytes(self.b.get(self.pos..self.pos + 4)?.try_into().ok()?);
        self.pos += 4;
        Some(v)
    }

    fn bytes(&mut self, n: usize) -> Option<&'a [u8]> {
        let v = self.b.get(self.pos..self.pos + n)?;
        self.pos += n;
        Some(v)
    }

    fn rest(&self) -> &'a [u8] {
        &self.b[self.pos..]
    }

    fn advance(&mut self, n: usize) {
        self.pos += n;
    }
}
