//! Length-prefixed framing over any `Read`/`Write` stream (TCP in
//! practice): `[u32 len][payload]`, 64 MiB frame cap.

use crate::error::{Error, Result};
use crate::net::codec::Message;
use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};

const MAX_FRAME: u32 = 64 << 20;

pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    if payload.len() as u32 > MAX_FRAME {
        return Err(Error::Protocol(format!("frame too large: {}", payload.len())));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len);
    if len > MAX_FRAME {
        return Err(Error::Protocol(format!("frame too large: {len}")));
    }
    let mut buf = vec![0u8; len as usize];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

/// A request/response connection carrying [`Message`]s.
pub struct FramedConn {
    stream: TcpStream,
}

impl FramedConn {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(FramedConn { stream })
    }

    /// Connect with a deadline on the dial *and* on every subsequent
    /// read/write. The DHT layer uses this so a dead peer costs one
    /// timeout, not a hung lookup (its liveness verdicts feed routing
    /// tables, which must converge under churn). Numeric `ip:port`
    /// addresses parse without touching the resolver; hostname
    /// addresses (operator-supplied `--bootstrap`/`--advertise`
    /// convenience) fall back to `getaddrinfo`, whose OS-level timeout
    /// is *not* bounded by `timeout` — peers that advertise slow or
    /// dead hostnames cost resolver time, so latency-sensitive swarms
    /// should advertise numeric addresses.
    pub fn connect_timeout(addr: &str, timeout: std::time::Duration) -> Result<Self> {
        let sockaddr = match addr.parse::<std::net::SocketAddr>() {
            Ok(a) => a,
            Err(_) => addr
                .to_socket_addrs()?
                .next()
                .ok_or_else(|| Error::Protocol(format!("unresolvable address: {addr}")))?,
        };
        let stream = TcpStream::connect_timeout(&sockaddr, timeout)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(timeout))?;
        stream.set_write_timeout(Some(timeout))?;
        Ok(FramedConn { stream })
    }

    pub fn from_stream(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true)?;
        Ok(FramedConn { stream })
    }

    pub fn send(&mut self, msg: &Message) -> Result<()> {
        write_frame(&mut self.stream, &msg.encode())
    }

    pub fn recv(&mut self) -> Result<Message> {
        let frame = read_frame(&mut self.stream)?;
        Message::decode(&frame)
            .ok_or_else(|| Error::Protocol("undecodable frame".into()))
    }

    /// One request/response round trip.
    pub fn call(&mut self, msg: &Message) -> Result<Message> {
        self.send(msg)?;
        let resp = self.recv()?;
        if let Message::Error { message } = &resp {
            return Err(Error::ChainBroken(message.clone()));
        }
        Ok(resp)
    }

    pub fn peer_addr(&self) -> String {
        self.stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "?".into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_various_sizes() {
        for n in [0usize, 1, 1000, 100_000] {
            let payload = vec![7u8; n];
            let mut buf = Vec::new();
            write_frame(&mut buf, &payload).unwrap();
            let got = read_frame(&mut &buf[..]).unwrap();
            assert_eq!(got, payload);
        }
    }

    #[test]
    fn oversize_frame_rejected() {
        // forged header claiming 1 GiB
        let mut buf = Vec::new();
        buf.extend_from_slice(&(1u32 << 30).to_le_bytes());
        assert!(read_frame(&mut &buf[..]).is_err());
    }

    #[test]
    fn truncated_frame_is_io_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &[1, 2, 3, 4]).unwrap();
        buf.truncate(buf.len() - 2);
        assert!(read_frame(&mut &buf[..]).is_err());
    }
}
