//! Dynamic blockwise int8 quantization — the §3.1 communication codec.
//!
//! Hidden states crossing the wire between pipeline stages are compressed
//! with the Dettmers et al. (2022b) dynamic blockwise scheme: absmax per
//! 64-element block → f32 scale + int8 payload. Wire cost per f32 element
//! drops from 4 B to 1 + 4/64 ≈ 1.0625 B (the paper's "halves bandwidth"
//! claim is vs f16).
//!
//! Bit-compatibility contract: this codec matches
//! `python/compile/kernels/{ref,quantize}.py` exactly — verified against
//! golden vectors in `quantize_hidden_*` artifacts (see tests) — so a
//! tensor may be quantized by the Pallas kernel on one node and
//! dequantized natively by Rust on another.

use crate::model::tensor::{DType, Tensor};

/// Elements per quantization block (mirrors `ref.QUANT_BLOCK`).
pub const QUANT_BLOCK: usize = 64;

/// A quantized hidden-state tensor as it travels on the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantizedTensor {
    pub shape: Vec<usize>,
    pub payload: Vec<i8>,
    pub scales: Vec<f32>,
}

impl QuantizedTensor {
    /// Bytes this tensor occupies on the wire (payload + scales).
    pub fn wire_bytes(&self) -> usize {
        self.payload.len() + self.scales.len() * 4
    }

    /// Compression ratio vs the uncompressed f32 form.
    pub fn ratio(&self) -> f64 {
        self.wire_bytes() as f64 / (self.payload.len() * 4) as f64
    }
}

/// Quantize an f32 tensor (length must be a multiple of [`QUANT_BLOCK`];
/// model hidden sizes guarantee this).
pub fn quantize(t: &Tensor) -> QuantizedTensor {
    let x = t.as_f32();
    assert_eq!(
        x.len() % QUANT_BLOCK,
        0,
        "tensor length {} not a multiple of {QUANT_BLOCK}",
        x.len()
    );
    let n_blocks = x.len() / QUANT_BLOCK;
    let mut payload = vec![0i8; x.len()];
    let mut scales = vec![0f32; n_blocks];
    for b in 0..n_blocks {
        let chunk = &x[b * QUANT_BLOCK..(b + 1) * QUANT_BLOCK];
        let absmax = chunk.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        let scale = if absmax == 0.0 { 1.0 } else { absmax / 127.0 };
        scales[b] = scale;
        let out = &mut payload[b * QUANT_BLOCK..(b + 1) * QUANT_BLOCK];
        for (o, &v) in out.iter_mut().zip(chunk) {
            // round-half-away-from-zero matches jnp.round (banker's
            // rounding differs only at exact .5 of the scaled value,
            // which absmax/127 scaling cannot produce for finite floats
            // except at the absmax itself where both round to ±127).
            *o = (v / scale).round_ties_even().clamp(-127.0, 127.0) as i8;
        }
    }
    QuantizedTensor { shape: t.shape.clone(), payload, scales }
}

/// Dequantize back to an f32 tensor.
pub fn dequantize(q: &QuantizedTensor) -> Tensor {
    let mut t = Tensor::zeros(&q.shape, DType::F32);
    let out = t.as_f32_mut();
    for (b, &scale) in q.scales.iter().enumerate() {
        let src = &q.payload[b * QUANT_BLOCK..(b + 1) * QUANT_BLOCK];
        let dst = &mut out[b * QUANT_BLOCK..(b + 1) * QUANT_BLOCK];
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = s as f32 * scale;
        }
    }
    t
}

/// Serialize for the wire: shape rank + dims + scales + payload.
pub fn encode(q: &QuantizedTensor) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + q.wire_bytes());
    out.extend_from_slice(&(q.shape.len() as u32).to_le_bytes());
    for &d in &q.shape {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    out.extend_from_slice(&(q.scales.len() as u32).to_le_bytes());
    for &s in &q.scales {
        out.extend_from_slice(&s.to_le_bytes());
    }
    out.extend_from_slice(unsafe {
        std::slice::from_raw_parts(q.payload.as_ptr() as *const u8, q.payload.len())
    });
    out
}

/// Inverse of [`encode`].
pub fn decode(buf: &[u8]) -> Option<QuantizedTensor> {
    let mut pos = 0;
    let rd_u32 = |pos: &mut usize| -> Option<u32> {
        let v = u32::from_le_bytes(buf.get(*pos..*pos + 4)?.try_into().ok()?);
        *pos += 4;
        Some(v)
    };
    let rank = rd_u32(&mut pos)? as usize;
    if rank > 8 {
        return None; // bound allocation on hostile input (codec cap)
    }
    let mut shape = Vec::with_capacity(rank);
    for _ in 0..rank {
        shape.push(rd_u32(&mut pos)? as usize);
    }
    let n_scales = rd_u32(&mut pos)? as usize;
    if n_scales > buf.len() / 4 {
        return None; // each scale needs 4 encoded bytes
    }
    let mut scales = Vec::with_capacity(n_scales);
    for _ in 0..n_scales {
        scales.push(f32::from_le_bytes(buf.get(pos..pos + 4)?.try_into().ok()?));
        pos += 4;
    }
    let n = n_scales * QUANT_BLOCK;
    let bytes = buf.get(pos..pos + n)?;
    let elems = shape.iter().try_fold(1usize, |a, &d| a.checked_mul(d));
    if elems != Some(n) {
        return None;
    }
    let payload = bytes.iter().map(|&b| b as i8).collect();
    Some(QuantizedTensor { shape, payload, scales })
}

/// Wire bytes for a hidden tensor of `elems` f32 elements under a codec.
pub fn wire_bytes(elems: usize, compressed: bool) -> u64 {
    if compressed {
        (elems + elems / QUANT_BLOCK * 4) as u64
    } else {
        (elems * 4) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    #[cfg(feature = "artifact-tests")]
    use crate::model::test_home;

    #[test]
    fn roundtrip_error_bound() {
        let vals: Vec<f32> = (0..256).map(|i| ((i as f32) * 0.37).sin() * 5.0).collect();
        let t = Tensor::from_f32(&[4, 64], &vals);
        let q = quantize(&t);
        let back = dequantize(&q);
        for (b, blk) in vals.chunks(QUANT_BLOCK).enumerate() {
            let absmax = blk.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            let bound = absmax / 127.0 * 0.5 + 1e-6;
            for (i, &v) in blk.iter().enumerate() {
                let r = back.as_f32()[b * QUANT_BLOCK + i];
                assert!((r - v).abs() <= bound, "block {b} elem {i}: {v} vs {r}");
            }
        }
    }

    #[test]
    fn zeros_stable() {
        let t = Tensor::zeros(&[2, 64], DType::F32);
        let q = quantize(&t);
        assert!(q.scales.iter().all(|&s| s == 1.0));
        assert!(q.payload.iter().all(|&p| p == 0));
        assert!(dequantize(&q).as_f32().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn encode_decode_roundtrip() {
        let vals: Vec<f32> = (0..128).map(|i| i as f32 - 64.0).collect();
        let t = Tensor::from_f32(&[2, 1, 64], &vals);
        let q = quantize(&t);
        let buf = encode(&q);
        let q2 = decode(&buf).unwrap();
        assert_eq!(q, q2);
    }

    #[test]
    fn decode_rejects_truncated() {
        let t = Tensor::from_f32(&[64], &[1.0; 64]);
        let buf = encode(&quantize(&t));
        for cut in [0, 3, 10, buf.len() - 1] {
            assert!(decode(&buf[..cut]).is_none(), "cut={cut}");
        }
    }

    #[test]
    fn compression_ratio_near_paper() {
        // 1.0625 bytes/elem vs 4 -> ~3.76x vs f32, i.e. ~1.9x vs f16:
        // the paper's "halves bandwidth".
        assert_eq!(wire_bytes(6400, true), 6400 + 400);
        assert_eq!(wire_bytes(6400, false), 25600);
    }

    /// Bit-compatibility with the Pallas kernel (golden artifacts).
    #[cfg(feature = "artifact-tests")]
    #[test]
    fn matches_pallas_golden() {
        let home = test_home();
        for entry in ["quantize_hidden_b1_s1", "quantize_hidden_b1_s128"] {
            let meta = &home.manifest.entries[entry];
            let golden = meta.golden.as_ref().unwrap();
            let input = home.load_tensor(&golden.inputs[0]).unwrap();
            let want_q = home.load_tensor(&golden.outputs[0]).unwrap();
            let want_s = home.load_tensor(&golden.outputs[1]).unwrap();
            let got = quantize(&input);
            assert_eq!(got.payload, want_q.as_i8(), "{entry} payload");
            let ws = want_s.as_f32();
            assert_eq!(got.scales.len(), ws.len());
            for (a, b) in got.scales.iter().zip(ws) {
                assert!((a - b).abs() <= f32::EPSILON * a.abs(), "{entry} scales");
            }
        }
    }
}
