//! # petals — reproduction of PETALS (ACL 2023)
//!
//! *Petals: Collaborative Inference and Fine-tuning of Large Models*
//! (Borzunov et al., ACL 2023 demo) as a three-layer Rust + JAX + Pallas
//! stack. This crate is Layer 3: the swarm coordinator. All model math is
//! AOT-compiled from JAX/Pallas to HLO text (`make artifacts`) and executed
//! through the PJRT C API ([`runtime`]); Python never runs on the request
//! path.
//!
//! ## Architecture
//!
//! - [`dht`] — Kademlia-style distributed hash table: how servers announce
//!   which Transformer blocks they hold (§3.2 of the paper), including
//!   KV-pool occupancy for load-aware placement (v2 entries) and hot
//!   prefix fingerprints for cache-aware sticky routing (v3). Three
//!   transports share the iterative-lookup logic: a filesystem bootstrap
//!   directory ([`dht::fs`]) for single-host swarms, a networked
//!   framed-TCP node ([`dht::node`], wire v4) for multi-host swarms, and
//!   the deterministic simulator ([`sim::dht`]) for metered experiments.
//! - [`server`] — a Petals *server*: hosts a contiguous span of blocks,
//!   keeps session KV caches in a paged, ref-counted pool
//!   ([`server::kvpool`]) with admission control and copy-on-write
//!   shared-prefix pages ([`server::prefixcache`]), and fuses concurrent
//!   sessions' decode steps into batched forwards ([`server::scheduler`]
//!   — continuous batching).
//! - [`coordinator`] — the client side: chain routing (beam search over
//!   per-block server sets), inference sessions with KV replay on failure,
//!   batch splitting for parallel forwards, and the server-side block
//!   assignment / rebalancing policy.
//! - [`draft`] — pluggable client-side draft sources for swarm
//!   speculative decoding (wire v8): an n-gram/suffix-match draft over
//!   the session's own history by default, trait-extensible to a small
//!   local model; drafts are verified by one fused `ProposeVerify`
//!   chain round instead of k per-token round-trips.
//! - [`rebalance`] — live block rebalancing: a server-side daemon that
//!   re-runs the greedy span selection against observed coverage (with
//!   hysteresis and per-identity jitter), then moves the server — a
//!   same-identity replacement node loads the new span, live sessions
//!   drain over wire-v6 migration, and discovery records are re-announced
//!   with proactive withdrawal of dropped block keys.
//! - [`net`] — transports: a deterministic bandwidth+latency simulator
//!   (used by the paper-table benches) and a real framed-TCP transport
//!   (used by the end-to-end examples).
//! - [`quant`] — dynamic blockwise int8 codec for hidden-state transfer
//!   (§3.1), bit-compatible with the Pallas kernel's format.
//! - [`offload`] — the RAM/SSD-offloading baseline Petals is compared
//!   against in Table 3.
//! - [`finetune`] — distributed parameter-efficient fine-tuning (§2.2):
//!   clients own soft prompts + heads; servers run frozen blocks fwd/bwd.
//! - [`hub`] — sharing trained adapters with tags and versions (§2.3).
//! - [`incentives`] — the points ledger sketched in §4.
//! - [`sim`] — discrete-event swarm scenarios regenerating Table 3, with
//!   a continuous-batching service model mirroring the real server.
//! - [`api`] — the client-facing HTTP API v2 (Figure 3): typed
//!   requests, chunked-NDJSON per-token streaming, raw hidden-state /
//!   logits access (`/api/v1/forward`, `/backward`), and persistent
//!   chat sessions with server-side KV reuse (`docs/HTTP_API.md`).
//! - [`model`] / [`runtime`] — artifact manifest, host tensors, weight
//!   packs, and the PJRT executor registry.
//! - [`config`] — JSON substrate, deterministic PRNG, device/network
//!   profiles behind every simulated Table-3 row.
//! - [`metrics`] — counters, gauges, histograms, windowed rates
//!   (lock-free record path) and the Prometheus `/metrics` exposition.
//! - [`trace`] — per-hop distributed tracing (wire v7): trace context,
//!   per-step stage breakdowns, the recent-traces ring behind
//!   `/api/v1/debug/traces` (`docs/OBSERVABILITY.md`).
//! - [`error`] — the crate-wide [`Error`] type; `Busy` signals
//!   admission-control rejections that clients should route around.
//!
//! See `rust/README.md` for the architecture walkthrough and
//! `docs/WIRE_PROTOCOL.md` for the framing and versioning rules.
//!
//! ## Quickstart
//!
//! ```no_run
//! use petals::model::ModelHome;
//! use petals::runtime::Runtime;
//!
//! let home = ModelHome::open("artifacts").unwrap();
//! let rt = Runtime::load(&home).unwrap();
//! // ... build a local swarm; see examples/quickstart.rs
//! ```

pub mod api;
pub mod config;
pub mod coordinator;
pub mod dht;
pub mod draft;
pub mod error;
pub mod finetune;
pub mod hub;
pub mod incentives;
pub mod metrics;
pub mod model;
pub mod net;
pub mod offload;
pub mod quant;
pub mod rebalance;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod trace;

pub use error::{Error, Result};
