//! The coordinator — Petals' system contribution (§2.1, §3.2).
//!
//! Split into pure decision logic (unit- and property-tested in
//! isolation) and the generic session machinery that drives any
//! [`ChainClient`] implementation (in-process cluster, TCP swarm, or the
//! discrete-event simulator):
//!
//! - [`throughput`] — server throughput estimation (compute ∧ network),
//!   the quantity servers announce to the DHT.
//! - [`balancer`] — block assignment: joining servers grab the
//!   contiguous interval with the worst coverage; periodic rebalancing
//!   closes gaps after departures.
//! - [`routing`] — client-side chain selection: beam search over
//!   per-block server sets minimizing predicted end-to-end step time.
//! - [`session`] — fault-tolerant inference sessions: chain formation,
//!   per-server KV position tracking, input history, replacement +
//!   replay on failure.
//! - [`batching`] — splitting parallel forward batches across server
//!   replicas proportional to throughput (fine-tuning & batch inference).
//! - [`client`] — the local model head: embeddings, LM head, sampling
//!   (the paper's "clients store token embeddings locally").

pub mod balancer;
pub mod batching;
pub mod client;
pub mod routing;
pub mod session;
pub mod throughput;

pub use balancer::{choose_join_span, plan_rebalance, swarm_throughput, BlockCoverage};
pub use routing::{find_chain, ChainHop, RouteQuery, ServerView};
pub use session::{ChainClient, InferenceSession, PongInfo, PromptShape, SessionConfig};
