//! The client's local model head (§2.1): "a client stores the model's
//! token embeddings locally and relies on servers to run Transformer
//! blocks". Embedding lookup, LM head, and sampling all run through
//! local AOT artifacts; the swarm only ever sees hidden states.

use crate::config::Rng;
use crate::coordinator::session::{ChainClient, InferenceSession, SessionConfig};
use crate::error::{Error, Result};
use crate::model::tensor::Tensor;
use crate::model::{ModelHome, Weights};
use crate::runtime::Runtime;
use std::sync::Arc;

/// Local embedding + LM head over AOT artifacts.
pub struct LocalHead {
    runtime: Arc<Runtime>,
    emb_lit: xla::Literal,
    ln_emb_g: xla::Literal,
    ln_emb_b: xla::Literal,
    ln_f_g: xla::Literal,
    ln_f_b: xla::Literal,
    pub hidden: usize,
    pub vocab: usize,
}

// Literals wrap PJRT host memory; the head is read-only after init.
unsafe impl Send for LocalHead {}
unsafe impl Sync for LocalHead {}

impl LocalHead {
    pub fn new(home: &ModelHome, runtime: Arc<Runtime>, weights: &Weights) -> Result<Self> {
        Ok(LocalHead {
            runtime,
            emb_lit: weights.embedding.to_literal()?,
            ln_emb_g: weights.ln_emb_g.to_literal()?,
            ln_emb_b: weights.ln_emb_b.to_literal()?,
            ln_f_g: weights.ln_f_g.to_literal()?,
            ln_f_b: weights.ln_f_b.to_literal()?,
            hidden: home.geometry().hidden,
            vocab: home.geometry().vocab,
        })
    }

    /// ids [B,S] -> hidden [B,S,H] via the `embed_b{B}_s{S}` artifact.
    pub fn embed(&self, ids: &Tensor) -> Result<Tensor> {
        let (b, s) = (ids.shape[0], ids.shape[1]);
        let name = format!("embed_b{b}_s{s}");
        let ex = self.runtime.entry(&name)?;
        let ids_lit = ids.to_literal()?;
        let out = ex.call_literals(&[&ids_lit, &self.emb_lit, &self.ln_emb_g, &self.ln_emb_b])?;
        ex.output_tensor(&out[0], 0)
    }

    /// hidden [B,H] -> logits [B,V] via `lm_head_b{B}`.
    pub fn lm_head(&self, h: &Tensor) -> Result<Tensor> {
        let b = h.shape[0];
        let name = format!("lm_head_b{b}");
        let ex = self.runtime.entry(&name)?;
        let h_lit = h.to_literal()?;
        let out = ex.call_literals(&[&h_lit, &self.ln_f_g, &self.ln_f_b, &self.emb_lit])?;
        ex.output_tensor(&out[0], 0)
    }
}

/// Token selection policies (Figure 2's `sample_next_token`).
#[derive(Debug, Clone)]
pub enum Sampler {
    Greedy,
    /// top-k sampling with temperature.
    TopK { k: usize, temperature: f32, seed: u64 },
}

impl Sampler {
    /// logits [B,V] -> one token per row.
    pub fn sample(&self, logits: &Tensor) -> Vec<i32> {
        let b = logits.shape[0];
        let v = logits.shape[1];
        let data = logits.as_f32();
        match self {
            Sampler::Greedy => (0..b)
                .map(|i| {
                    let row = &data[i * v..(i + 1) * v];
                    argmax(row) as i32
                })
                .collect(),
            Sampler::TopK { k, temperature, seed } => {
                let mut rng = Rng::new(*seed);
                (0..b)
                    .map(|i| {
                        let row = &data[i * v..(i + 1) * v];
                        sample_topk(row, *k, *temperature, &mut rng) as i32
                    })
                    .collect()
            }
        }
    }
}

fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

fn sample_topk(row: &[f32], k: usize, temperature: f32, rng: &mut Rng) -> usize {
    let mut idx: Vec<usize> = (0..row.len()).collect();
    idx.sort_by(|&a, &b| row[b].total_cmp(&row[a]));
    idx.truncate(k.max(1));
    let t = temperature.max(1e-4);
    let mx = row[idx[0]];
    let weights: Vec<f64> = idx.iter().map(|&i| (((row[i] - mx) / t) as f64).exp()).collect();
    let total: f64 = weights.iter().sum();
    let mut r = rng.f64() * total;
    for (j, w) in weights.iter().enumerate() {
        r -= w;
        if r <= 0.0 {
            return idx[j];
        }
    }
    idx[0]
}

/// Generation outcome + stats for one request.
#[derive(Debug, Clone)]
pub struct GenerationResult {
    /// [B][n_new] generated tokens.
    pub tokens: Vec<Vec<i32>>,
    pub steps: usize,
    pub recoveries: usize,
    pub wall: std::time::Duration,
}

/// End-to-end generation driver: local embed/head + remote blocks —
/// the Rust rendition of Figure 2's inference-session snippet.
pub struct SwarmGenerator<'a, C: ChainClient> {
    pub swarm: &'a C,
    pub head: &'a LocalHead,
    pub cfg: SessionConfig,
    pub sampler: Sampler,
}

impl<'a, C: ChainClient> SwarmGenerator<'a, C> {
    /// Greedy/top-k generation of `n_new` tokens from `prefix` ids
    /// [B, prefix_len].
    pub fn generate(&self, prefix: &[Vec<i32>], n_new: usize, session_id: u64) -> Result<GenerationResult> {
        let started = std::time::Instant::now();
        let b = prefix.len();
        let prefix_len = prefix.first().map(|p| p.len()).unwrap_or(0);
        if b != self.cfg.batch || prefix_len != self.cfg.prefix_len {
            return Err(Error::Shape(format!(
                "prefix {b}x{prefix_len} vs session config {}x{}",
                self.cfg.batch, self.cfg.prefix_len
            )));
        }
        // pad prefix ids to the prefill width (causal masking makes the
        // padding invisible to valid positions; servers track cache_len)
        let w = self.cfg.prefill_width;
        let mut ids = vec![0i32; b * w];
        for (i, row) in prefix.iter().enumerate() {
            ids[i * w..i * w + prefix_len].copy_from_slice(row);
        }
        let ids_t = Tensor::from_i32(&[b, w], &ids);
        let h0 = self.head.embed(&ids_t)?;

        // thread prefix identity end-to-end: batch-1 sessions carry their
        // prompt token ids so servers can attach cached shared-prefix KV
        // pages (wire v3) and routing can stick to servers that already
        // hold the prefix (cache-aware sticky routing)
        let mut cfg = self.cfg.clone();
        if b == 1 {
            if cfg.prefix_tokens.is_empty() {
                cfg.prefix_tokens = prefix[0].clone();
            } else if cfg.prefix_tokens != prefix[0] {
                // the declared identity MUST be the whole prompt: a
                // shorter "template" declaration would full-hit another
                // session's registration and be served *its* cached
                // prefill output — silently wrong tokens
                return Err(Error::Protocol(
                    "cfg.prefix_tokens must equal the batch-1 prompt exactly".into(),
                ));
            }
        } else if !cfg.prefix_tokens.is_empty() {
            return Err(Error::Protocol("prefix_tokens requires batch 1".into()));
        }
        if cfg.route.prefix_fp.is_none() && !cfg.prefix_tokens.is_empty() {
            // hint over the page-aligned leading span, so prompts sharing
            // a template (but not a suffix) still route sticky
            cfg.route.prefix_fp = Some(crate::server::prefixcache::template_fingerprint(
                &cfg.prefix_tokens,
                crate::server::PAGE_TOKENS,
            ));
        }
        let mut session = InferenceSession::open(self.swarm, cfg, session_id)?;
        let h_pre = session.prefill(h0)?;

        // last *valid* position of the prefill output
        let hidden = self.head.hidden;
        let mut last = Tensor::from_f32(
            &[b, hidden],
            &extract_positions(&h_pre, prefix_len - 1),
        );
        let mut tokens: Vec<Vec<i32>> = vec![Vec::with_capacity(n_new); b];
        for _step in 0..n_new {
            let logits = self.head.lm_head(&last)?;
            let next = self.sampler.sample(&logits);
            for (row, &t) in tokens.iter_mut().zip(&next) {
                row.push(t);
            }
            // embed the new tokens and run one decode step
            let ids_t = Tensor::from_i32(&[b, 1], &next);
            let h = self.head.embed(&ids_t)?;
            let h_out = session.step(h)?;
            last = Tensor::from_f32(&[b, hidden], h_out.as_f32());
        }
        let recoveries = session.recoveries();
        let steps = n_new;
        session.close();
        Ok(GenerationResult { tokens, steps, recoveries, wall: started.elapsed() })
    }
}

/// Pull position `pos` out of a [B,S,H] tensor -> flat [B*H].
fn extract_positions(h: &Tensor, pos: usize) -> Vec<f32> {
    let (b, s, hd) = (h.shape[0], h.shape[1], h.shape[2]);
    assert!(pos < s);
    let src = h.as_f32();
    let mut out = Vec::with_capacity(b * hd);
    for i in 0..b {
        let off = (i * s + pos) * hd;
        out.extend_from_slice(&src[off..off + hd]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_argmax() {
        let logits = Tensor::from_f32(&[2, 4], &[0.1, 0.9, 0.0, 0.2, 5.0, 1.0, 2.0, 3.0]);
        assert_eq!(Sampler::Greedy.sample(&logits), vec![1, 0]);
    }

    #[test]
    fn topk_respects_k() {
        let logits = Tensor::from_f32(&[1, 5], &[10.0, 9.0, -50.0, -50.0, -50.0]);
        let s = Sampler::TopK { k: 2, temperature: 1.0, seed: 1 };
        for trial in 0..20 {
            let s = Sampler::TopK { k: 2, temperature: 1.0, seed: trial };
            let t = s.sample(&logits)[0];
            assert!(t == 0 || t == 1, "token {t} outside top-2");
        }
        let _ = s;
    }

    #[test]
    fn topk_deterministic_per_seed() {
        let logits = Tensor::from_f32(&[1, 8], &[1.0, 2.0, 3.0, 4.0, 3.5, 2.5, 1.5, 0.5]);
        let a = Sampler::TopK { k: 4, temperature: 0.8, seed: 7 }.sample(&logits);
        let b = Sampler::TopK { k: 4, temperature: 0.8, seed: 7 }.sample(&logits);
        assert_eq!(a, b);
    }

    #[test]
    fn extract_positions_layout() {
        // B=2,S=3,H=2
        let h = Tensor::from_f32(
            &[2, 3, 2],
            &[0., 1., 10., 11., 20., 21., 100., 101., 110., 111., 120., 121.],
        );
        assert_eq!(extract_positions(&h, 1), vec![10., 11., 110., 111.]);
        assert_eq!(extract_positions(&h, 2), vec![20., 21., 120., 121.]);
    }
}
