//! The client's local model head (§2.1): "a client stores the model's
//! token embeddings locally and relies on servers to run Transformer
//! blocks". Embedding lookup, LM head, and sampling all run through
//! local AOT artifacts; the swarm only ever sees hidden states.
//!
//! Since the streaming-API redesign, generation is **pull-based**:
//! [`SwarmGenerator::stream`] opens a session, prefills, and returns a
//! [`GenerationStream`] that yields one [`TokenStep`] per call —
//! `{token, step_s, logits?, hidden?}` — with server failure recovery
//! happening transparently *between* steps. The batch path
//! ([`SwarmGenerator::generate`]) is a `collect()` over the same stream,
//! so batch and streaming callers share one code path and produce
//! bitwise-identical token sequences.

use crate::config::Rng;
use crate::coordinator::session::{ChainClient, InferenceSession, PromptShape, SessionConfig};
use crate::error::{Error, Result};
use crate::model::tensor::Tensor;
use crate::model::{ModelHome, Weights};
use crate::runtime::Runtime;
use crate::trace::{fresh_span_id, fresh_trace_id, StepTrace, TraceContext};
use std::sync::Arc;

/// Local embedding + LM head over AOT artifacts.
pub struct LocalHead {
    runtime: Arc<Runtime>,
    emb_lit: xla::Literal,
    ln_emb_g: xla::Literal,
    ln_emb_b: xla::Literal,
    ln_f_g: xla::Literal,
    ln_f_b: xla::Literal,
    pub hidden: usize,
    pub vocab: usize,
}

// Literals wrap PJRT host memory; the head is read-only after init.
unsafe impl Send for LocalHead {}
unsafe impl Sync for LocalHead {}

impl LocalHead {
    pub fn new(home: &ModelHome, runtime: Arc<Runtime>, weights: &Weights) -> Result<Self> {
        Ok(LocalHead {
            runtime,
            emb_lit: weights.embedding.to_literal()?,
            ln_emb_g: weights.ln_emb_g.to_literal()?,
            ln_emb_b: weights.ln_emb_b.to_literal()?,
            ln_f_g: weights.ln_f_g.to_literal()?,
            ln_f_b: weights.ln_f_b.to_literal()?,
            hidden: home.geometry().hidden,
            vocab: home.geometry().vocab,
        })
    }

    /// ids [B,S] -> hidden [B,S,H] via the `embed_b{B}_s{S}` artifact.
    pub fn embed(&self, ids: &Tensor) -> Result<Tensor> {
        let (b, s) = (ids.shape[0], ids.shape[1]);
        let name = format!("embed_b{b}_s{s}");
        let ex = self.runtime.entry(&name)?;
        let ids_lit = ids.to_literal()?;
        let out = ex.call_literals(&[&ids_lit, &self.emb_lit, &self.ln_emb_g, &self.ln_emb_b])?;
        ex.output_tensor(&out[0], 0)
    }

    /// hidden [B,H] -> logits [B,V] via `lm_head_b{B}`.
    pub fn lm_head(&self, h: &Tensor) -> Result<Tensor> {
        let b = h.shape[0];
        let name = format!("lm_head_b{b}");
        let ex = self.runtime.entry(&name)?;
        let h_lit = h.to_literal()?;
        let out = ex.call_literals(&[&h_lit, &self.ln_f_g, &self.ln_f_b, &self.emb_lit])?;
        ex.output_tensor(&out[0], 0)
    }

    /// The prefill widths compiled for `batch` (from the loaded
    /// `embed_b{batch}_s{W}` artifacts; the AOT exporter emits matching
    /// `block_prefill` entries for every width, so this is also the set
    /// of widths the swarm can serve), sorted ascending.
    pub fn prefill_widths(&self, batch: usize) -> Vec<usize> {
        parse_embed_widths(self.runtime.entry_names().map(|s| s.as_str()), batch)
    }

    /// Pick the smallest compiled prefill width that fits a
    /// `prompt_len`-token prompt — the variable-length-prompt half of
    /// the API redesign. Padding (after the valid positions, causally
    /// invisible) covers the gap; a prompt longer than every compiled
    /// width is rejected with [`Error::PromptTooLong`] instead of being
    /// truncated.
    pub fn derive_prefill_width(&self, batch: usize, prompt_len: usize) -> Result<usize> {
        let widths = self.prefill_widths(batch);
        widths
            .iter()
            .copied()
            .find(|&w| w >= prompt_len)
            .ok_or_else(|| {
                Error::PromptTooLong(format!(
                    "{prompt_len} tokens exceeds the largest compiled prefill width {} (batch {batch})",
                    widths.last().copied().unwrap_or(0)
                ))
            })
    }
}

/// Parse the widths of `embed_b{batch}_s{W}` entry names (W > 1 —
/// `_s1` is the decode-step embed, not a prefill shape). Pure so the
/// derivation logic is testable without artifacts.
pub fn parse_embed_widths<'a>(
    names: impl Iterator<Item = &'a str>,
    batch: usize,
) -> Vec<usize> {
    let prefix = format!("embed_b{batch}_s");
    let mut widths: Vec<usize> = names
        .filter_map(|n| n.strip_prefix(&prefix))
        .filter_map(|w| w.parse::<usize>().ok())
        .filter(|&w| w > 1)
        .collect();
    widths.sort_unstable();
    widths.dedup();
    widths
}

/// Token selection policies (Figure 2's `sample_next_token`).
#[derive(Debug, Clone)]
pub enum Sampler {
    Greedy,
    /// top-k sampling with temperature.
    TopK { k: usize, temperature: f32, seed: u64 },
    /// Nucleus sampling: the smallest set of tokens whose softmax mass
    /// reaches `p` (at least one). `p >= 1.0` is temperature sampling
    /// over the full vocabulary; `p -> 0` degenerates to greedy.
    TopP { p: f32, temperature: f32, seed: u64 },
}

impl Sampler {
    /// Start a stateful sampling run: the RNG is seeded once and then
    /// *advances across steps*, so a fixed seed yields a deterministic
    /// (but non-repeating) token sequence.
    pub fn start(&self) -> SamplerState {
        let rng = match self {
            Sampler::Greedy => Rng::new(0),
            Sampler::TopK { seed, .. } | Sampler::TopP { seed, .. } => Rng::new(*seed),
        };
        SamplerState { sampler: self.clone(), rng }
    }

    /// One-shot sampling of a single logits batch (fresh RNG from the
    /// seed). Generation loops should use [`Sampler::start`] instead so
    /// successive steps draw different randomness.
    pub fn sample(&self, logits: &Tensor) -> Vec<i32> {
        self.start().sample(logits)
    }
}

/// A [`Sampler`] plus its advancing RNG — one per generation stream.
#[derive(Debug, Clone)]
pub struct SamplerState {
    sampler: Sampler,
    rng: Rng,
}

impl SamplerState {
    /// Export the RNG's raw state. Together with the [`Sampler`] policy
    /// this is the complete sampler snapshot: a stream resumed via
    /// [`Self::restore`] draws the exact sequence the uninterrupted run
    /// would have.
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// Rebuild a mid-stream sampler from a policy + an exported
    /// [`Self::rng_state`] (session durability: resumed streams must not
    /// re-seed, which would fork the token sequence).
    pub fn restore(sampler: Sampler, rng_state: [u64; 4]) -> Self {
        SamplerState { sampler, rng: Rng::from_state(rng_state) }
    }

    /// logits [B,V] -> one token per row.
    pub fn sample(&mut self, logits: &Tensor) -> Vec<i32> {
        let b = logits.shape[0];
        let v = logits.shape[1];
        let data = logits.as_f32();
        (0..b)
            .map(|i| {
                let row = &data[i * v..(i + 1) * v];
                match &self.sampler {
                    Sampler::Greedy => argmax(row) as i32,
                    Sampler::TopK { k, temperature, .. } => {
                        sample_topk(row, *k, *temperature, &mut self.rng) as i32
                    }
                    Sampler::TopP { p, temperature, .. } => {
                        sample_topp(row, *p, *temperature, &mut self.rng) as i32
                    }
                }
            })
            .collect()
    }
}

fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Indices sorted by descending logit. `sort_by` is stable, so ties
/// keep index order and element 0 always equals `argmax` — the property
/// that makes `top_p -> 0` exactly greedy.
fn sorted_desc(row: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..row.len()).collect();
    idx.sort_by(|&a, &b| row[b].total_cmp(&row[a]));
    idx
}

/// Inverse-CDF draw over `weights[..n]` (unnormalized); returns the
/// chosen position in `idx`.
fn draw(idx: &[usize], weights: &[f64], n: usize, rng: &mut Rng) -> usize {
    let total: f64 = weights[..n].iter().sum();
    let mut r = rng.f64() * total;
    for (j, w) in weights[..n].iter().enumerate() {
        r -= w;
        if r <= 0.0 {
            return idx[j];
        }
    }
    idx[n - 1]
}

fn softmax_weights(row: &[f32], idx: &[usize], temperature: f32) -> Vec<f64> {
    let t = temperature.max(1e-4);
    let mx = row[idx[0]];
    idx.iter().map(|&i| (((row[i] - mx) / t) as f64).exp()).collect()
}

fn sample_topk(row: &[f32], k: usize, temperature: f32, rng: &mut Rng) -> usize {
    let idx = sorted_desc(row);
    let n = k.clamp(1, idx.len());
    let weights = softmax_weights(row, &idx, temperature);
    draw(&idx, &weights, n, rng)
}

/// Nucleus (top-p) sampling: keep the smallest descending-probability
/// prefix whose mass reaches `p * total`, then draw from it. Weights are
/// accumulated in the same order as the total, so `p = 1.0` keeps the
/// entire vocabulary bit-exactly (temperature-softmax sampling) and
/// `p = 0.0` keeps exactly the argmax (greedy).
fn sample_topp(row: &[f32], p: f32, temperature: f32, rng: &mut Rng) -> usize {
    let idx = sorted_desc(row);
    let weights = softmax_weights(row, &idx, temperature);
    let total: f64 = weights.iter().sum();
    let target = (p.clamp(0.0, 1.0) as f64) * total;
    let mut cum = 0.0f64;
    let mut n = 1;
    for (j, w) in weights.iter().enumerate() {
        cum += w;
        if cum >= target {
            n = j + 1;
            break;
        }
        n = j + 1;
    }
    draw(&idx, &weights, n, rng)
}

/// Generation outcome + stats for one request.
#[derive(Debug, Clone)]
pub struct GenerationResult {
    /// [B][n_new] generated tokens.
    pub tokens: Vec<Vec<i32>>,
    pub steps: usize,
    pub recoveries: usize,
    pub wall: std::time::Duration,
    /// Why generation ended.
    pub finish: FinishReason,
    /// Speculative-decoding counters (all zero on non-spec runs).
    pub spec: SpecStats,
}

/// Why a generation stream ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// `max_new` tokens were produced.
    Length,
    /// A stop token was sampled.
    Stop,
}

impl FinishReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Stop => "stop",
        }
    }
}

/// Per-request generation knobs for [`SwarmGenerator::stream`].
#[derive(Debug, Clone, Default)]
pub struct GenOptions {
    /// Tokens to generate (the stream ends earlier on a stop token).
    pub max_new: usize,
    /// Sampling any of these finishes the sampling row (the stop token
    /// itself is still reported). Per-row for multi-prompt batches: a
    /// finished row exits the ragged session immediately — its KV pages
    /// free on every hop for concurrent sessions to reuse — while the
    /// remaining rows keep decoding; the stream ends when every row has
    /// stopped (or at `max_new`).
    pub stop_tokens: Vec<i32>,
    /// Attach the logits that produced each token to its [`TokenStep`].
    pub want_logits: bool,
    /// Attach the pre-LM-head hidden state to each [`TokenStep`] — the
    /// "natively exposes hidden states" differentiator.
    pub want_hidden: bool,
    /// Carry a wire-v7 trace context on every decode step and attach the
    /// per-hop timing waterfall to each [`TokenStep`]. Opt-in: untraced
    /// streams send the classic frames and pay zero overhead.
    pub trace: bool,
    /// Swarm speculative decoding (wire v8): a local draft proposes up
    /// to `max_k` candidate tokens per round and ONE fused
    /// `ProposeVerify` chain round scores them all, so an accepted draft
    /// costs no extra chain round-trip. The emitted token sequence is
    /// bitwise identical to non-speculative decoding (the sampler draws
    /// from the same logits in the same order either way). Active only
    /// for batch-1 untraced streams: multi-row batches and traced steps
    /// fall back to plain per-token decoding silently.
    pub speculation: Option<crate::draft::SpecOptions>,
}

/// One per-token event from a [`GenerationStream`].
#[derive(Debug, Clone)]
pub struct TokenStep {
    /// The sampled token, one per batch row. Rows that already stopped
    /// (`active[r] == false`) still occupy a slot so the batch keeps its
    /// shape, but their value is padding, not output.
    pub tokens: Vec<i32>,
    /// Which rows were still producing when this step sampled
    /// (`active.len() == tokens.len()`).
    pub active: Vec<bool>,
    /// 0-based step index.
    pub step: usize,
    /// Wall time this step took (lm_head + sample + decode step).
    pub step_s: f64,
    /// Logits [B,V] that produced `tokens` (if requested).
    pub logits: Option<Tensor>,
    /// Final-layer hidden state [B,H] that produced `logits` (if
    /// requested).
    pub hidden: Option<Tensor>,
    /// Per-hop timing waterfall for the decode step that FOLLOWED this
    /// token (when [`GenOptions::trace`] is set and a step ran — the
    /// final token of a stream has no decode step, hence no trace).
    pub trace: Option<StepTrace>,
    /// Whether this token was proposed by the speculative draft and
    /// accepted by verification — i.e. it cost no chain round-trip of
    /// its own. Always `false` on non-speculative streams.
    pub accepted: bool,
}

/// Aggregate speculative-decoding counters for one stream.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpecStats {
    /// Draft tokens proposed into verify rounds.
    pub proposed: u64,
    /// Draft tokens accepted (`accepted / proposed` = acceptance rate).
    pub accepted: u64,
    /// Verify rounds run (each costs one chain round-trip).
    pub rounds: u64,
}

/// End-to-end generation driver: local embed/head + remote blocks —
/// the Rust rendition of Figure 2's inference-session snippet.
pub struct SwarmGenerator<'a, C: ChainClient> {
    pub swarm: &'a C,
    pub head: &'a LocalHead,
    pub cfg: SessionConfig,
    pub sampler: Sampler,
}

impl<'a, C: ChainClient> SwarmGenerator<'a, C> {
    /// Open a session for `prefix` ids ([B] rows of token ids — rows may
    /// have DIFFERENT lengths since the ragged refactor), run the
    /// prefill, and return a pull-based stream yielding one token per
    /// row per [`GenerationStream::next_step`] call. A multi-prompt
    /// request of mixed lengths travels as ONE ragged session (per-row
    /// cache lengths server-side) instead of N sessions. The prefill
    /// width is derived from the longest row (smallest compiled width
    /// that fits); over-long prompts fail with [`Error::PromptTooLong`].
    pub fn stream(
        &self,
        prefix: &[Vec<i32>],
        opts: GenOptions,
        session_id: u64,
    ) -> Result<GenerationStream<'a, C>> {
        let started = std::time::Instant::now();
        let b = prefix.len();
        let row_lens: Vec<usize> = prefix.iter().map(|p| p.len()).collect();
        let prefix_len = row_lens.iter().copied().max().unwrap_or(0);
        if b == 0 || row_lens.iter().any(|&l| l == 0) {
            return Err(Error::Shape("empty prompt".into()));
        }
        // prefill width derived from the longest prompt, not caller-
        // configured; each row's padding sits AFTER its valid positions
        // (per-row causal masking keeps it invisible; servers track one
        // cache length per row)
        let w = self.head.derive_prefill_width(b, prefix_len)?;
        let shape = PromptShape { batch: b, prefix_len, prefill_width: w };
        let mut ids = vec![0i32; b * w];
        for (i, row) in prefix.iter().enumerate() {
            ids[i * w..i * w + row.len()].copy_from_slice(row);
        }
        let ids_t = Tensor::from_i32(&[b, w], &ids);
        let h0 = self.head.embed(&ids_t)?;

        // thread prefix identity end-to-end: batch-1 sessions carry their
        // prompt token ids so servers can attach cached shared-prefix KV
        // pages (wire v3) and routing can stick to servers that already
        // hold the prefix (cache-aware sticky routing). Multi-row
        // sessions declare the rows' LONGEST COMMON PREFIX — the shared
        // template every row can alias (servers attach it to every row
        // and degrade full hits to partial, so a declared template never
        // substitutes one row's cached prefill for another's).
        let mut cfg = self.cfg.clone();
        if b == 1 {
            if cfg.prefix_tokens.is_empty() {
                cfg.prefix_tokens = prefix[0].clone();
            } else if cfg.prefix_tokens != prefix[0] {
                // the declared identity MUST be the whole prompt: a
                // shorter "template" declaration would full-hit another
                // session's registration and be served *its* cached
                // prefill output — silently wrong tokens
                return Err(Error::Protocol(
                    "cfg.prefix_tokens must equal the batch-1 prompt exactly".into(),
                ));
            }
        } else {
            let lcp = common_prefix(prefix);
            if cfg.prefix_tokens.is_empty() {
                cfg.prefix_tokens = lcp;
            } else if !lcp.starts_with(&cfg.prefix_tokens) {
                return Err(Error::Protocol(
                    "cfg.prefix_tokens must be a common prefix of every row".into(),
                ));
            }
        }
        if cfg.route.prefix_fp.is_none() && !cfg.prefix_tokens.is_empty() {
            // hint over the page-aligned leading span, so prompts sharing
            // a template (but not a suffix) still route sticky
            cfg.route.prefix_fp = Some(crate::server::prefixcache::template_fingerprint(
                &cfg.prefix_tokens,
                crate::server::PAGE_TOKENS,
            ));
        }
        let sampler = self.sampler.start();
        let mut session =
            InferenceSession::open_ragged(self.swarm, cfg, shape, row_lens.clone(), session_id)?;
        let h_pre = match session.prefill(h0) {
            Ok(h) => h,
            Err(e) => {
                // a failed prefill must not strand the per-server opens
                session.close();
                return Err(e);
            }
        };

        // last *valid* position of each row's prefill output
        let hidden = self.head.hidden;
        let last = Tensor::from_f32(&[b, hidden], &extract_row_positions(&h_pre, &row_lens));
        // one trace id per stream; each decode step becomes a span under it
        let trace_ctx = opts.trace.then(|| TraceContext {
            trace_id: fresh_trace_id(),
            parent_span: fresh_span_id(),
        });
        let prompt0 = prefix[0].clone();
        Ok(GenerationStream {
            head: self.head,
            session: Some(session),
            sampler,
            opts,
            trace_ctx,
            last,
            produced: vec![Vec::new(); b],
            row_done: vec![false; b],
            steps: 0,
            finish: None,
            recoveries: 0,
            started,
            batch: b,
            prompt0,
            spec_buf: std::collections::VecDeque::new(),
            spec_stats: SpecStats::default(),
        })
    }

    /// Batch generation of `n_new` tokens from `prefix` ids
    /// [B, prefix_len] — a `collect()` over [`SwarmGenerator::stream`],
    /// so batch and streaming callers share one code path and produce
    /// identical tokens.
    pub fn generate(
        &self,
        prefix: &[Vec<i32>],
        n_new: usize,
        session_id: u64,
    ) -> Result<GenerationResult> {
        let opts = GenOptions { max_new: n_new, ..Default::default() };
        self.stream(prefix, opts, session_id)?.finish()
    }
}

/// A live pull-based generation: each [`GenerationStream::next_step`]
/// call samples one token, reports it (with optional logits / hidden
/// states), and advances the swarm session by one decode step. Server
/// failures recover transparently inside the step, exactly as in the
/// batch path. Dropping the stream closes the session.
pub struct GenerationStream<'a, C: ChainClient> {
    head: &'a LocalHead,
    session: Option<InferenceSession<&'a C>>,
    sampler: SamplerState,
    opts: GenOptions,
    /// `Some` when [`GenOptions::trace`] was set: the stream's trace id.
    trace_ctx: Option<TraceContext>,
    /// Hidden state [B,H] feeding the next lm_head call.
    last: Tensor,
    produced: Vec<Vec<i32>>,
    /// Rows that sampled a stop token and exited the batch early (their
    /// KV pages are already freed server-side via `close_row`).
    row_done: Vec<bool>,
    steps: usize,
    finish: Option<FinishReason>,
    recoveries: usize,
    started: std::time::Instant,
    batch: usize,
    /// Row 0's prompt ids — the draft source's history root (speculative
    /// streams are batch-1, so row 0 IS the stream).
    prompt0: Vec<i32>,
    /// Tokens a verify round has emitted but [`Self::next_step`] has not
    /// yet handed out — popped one per call so speculative and plain
    /// streams present the identical per-token interface.
    spec_buf: std::collections::VecDeque<PendingTok>,
    spec_stats: SpecStats,
}

/// One buffered speculative emission awaiting its [`TokenStep`].
struct PendingTok {
    token: i32,
    accepted: bool,
    logits: Option<Tensor>,
    hidden: Option<Tensor>,
}

impl<'a, C: ChainClient> GenerationStream<'a, C> {
    /// Whether this stream runs the speculative accept/rollback loop:
    /// configured, batch-1, untraced (the verify frame carries no trace
    /// context, so traced streams keep the per-step waterfall instead).
    fn spec_active(&self) -> bool {
        self.batch == 1 && self.trace_ctx.is_none() && self.opts.speculation.is_some()
    }

    /// Produce the next token, or `None` when generation is complete
    /// (the session is closed at that point).
    pub fn next_step(&mut self) -> Result<Option<TokenStep>> {
        if self.spec_active() {
            return self.next_step_spec();
        }
        if self.finish.is_some() || self.steps >= self.opts.max_new {
            if self.finish.is_none() {
                self.finish = Some(FinishReason::Length);
            }
            self.close_session();
            return Ok(None);
        }
        let t0 = std::time::Instant::now();
        let logits = self.head.lm_head(&self.last)?;
        let next = self.sampler.sample(&logits);
        let active: Vec<bool> = self.row_done.iter().map(|&d| !d).collect();
        for (row, (produced, &t)) in self.produced.iter_mut().zip(&next).enumerate() {
            if !self.row_done[row] {
                produced.push(t);
            }
        }
        let hidden_out = self.opts.want_hidden.then(|| self.last.clone());
        let step = self.steps;
        self.steps += 1;
        // per-row stop: a row that samples a stop token exits the batch
        // NOW — its KV pages free on every hop while the rest keep
        // decoding (the freed pages are immediately reusable by
        // concurrent sessions; the batch keeps its shape)
        if !self.opts.stop_tokens.is_empty() {
            for (row, &t) in next.iter().enumerate() {
                if !self.row_done[row] && self.opts.stop_tokens.contains(&t) {
                    self.row_done[row] = true;
                    if let Some(session) = &self.session {
                        session.close_row(row);
                    }
                }
            }
        }
        if self.row_done.iter().all(|&d| d) {
            self.finish = Some(FinishReason::Stop);
        } else if self.steps >= self.opts.max_new {
            self.finish = Some(FinishReason::Length);
        }
        let mut trace = None;
        if self.finish.is_none() {
            // embed the new tokens and run one decode step through the
            // chain (recovery/re-routing happens inside `session.step`)
            let ids_t = Tensor::from_i32(&[self.batch, 1], &next);
            let h = self.head.embed(&ids_t)?;
            let session = self
                .session
                .as_mut()
                .ok_or_else(|| Error::Protocol("stream already closed".into()))?;
            let h_out = match &self.trace_ctx {
                Some(ctx) => {
                    let ts = std::time::Instant::now();
                    let (h_out, hops) = session.step_traced(h, ctx)?;
                    trace = Some(StepTrace {
                        trace_id: ctx.trace_id,
                        step,
                        client_us: ts.elapsed().as_micros() as u64,
                        hops,
                    });
                    h_out
                }
                None => session.step(h)?,
            };
            self.last = Tensor::from_f32(&[self.batch, self.head.hidden], h_out.as_f32());
        } else {
            // the final token needs no decode step — nothing will read
            // the cache column it would have written
            self.close_session();
        }
        Ok(Some(TokenStep {
            tokens: next,
            active,
            step,
            step_s: t0.elapsed().as_secs_f64(),
            logits: self.opts.want_logits.then_some(logits),
            hidden: hidden_out,
            trace,
            accepted: false,
        }))
    }

    /// The speculative twin of [`Self::next_step`]: when the emission
    /// buffer is dry, run one verify round (which yields 1..=max_k+1
    /// tokens for a single chain round-trip) and then hand tokens out
    /// one per call. The emitted sequence is bitwise identical to the
    /// plain path: every token is sampled from the true model's logits
    /// at its position, in order, consuming the sampler RNG exactly as
    /// plain decoding would.
    fn next_step_spec(&mut self) -> Result<Option<TokenStep>> {
        if self.finish.is_some() || self.steps >= self.opts.max_new {
            if self.finish.is_none() {
                self.finish = Some(FinishReason::Length);
            }
            self.close_session();
            return Ok(None);
        }
        let t0 = std::time::Instant::now();
        if self.spec_buf.is_empty() {
            self.run_verify_round()?;
        }
        let pending = self
            .spec_buf
            .pop_front()
            .ok_or_else(|| Error::Protocol("verify round emitted no tokens".into()))?;
        let step = self.steps;
        self.steps += 1;
        let token = pending.token;
        self.produced[0].push(token);
        if !self.opts.stop_tokens.is_empty() && self.opts.stop_tokens.contains(&token) {
            // tokens buffered past a stop would never have been sampled
            // by plain decoding — they are not output (their RNG draws
            // happened, but the stream ends here so nothing observes it)
            self.row_done[0] = true;
            self.finish = Some(FinishReason::Stop);
            self.spec_buf.clear();
            self.close_session();
        } else if self.steps >= self.opts.max_new {
            self.finish = Some(FinishReason::Length);
            self.spec_buf.clear();
            self.close_session();
        }
        Ok(Some(TokenStep {
            tokens: vec![token],
            active: vec![true],
            step,
            step_s: t0.elapsed().as_secs_f64(),
            logits: pending.logits,
            hidden: pending.hidden,
            trace: None,
            accepted: pending.accepted,
        }))
    }

    /// Run one speculative round and refill the emission buffer.
    ///
    /// Anchor-token scheme: the newest emitted token is not yet in the
    /// swarm's KV (its decode was deferred); this round sends
    /// `[anchor, d_1..d_q]` as one `ProposeVerify` frame, getting back
    /// the chain outputs `o_0..o_q` for all positions. The client then
    /// samples sequentially: `s_1 = sample(lm_head(o_0))` is emitted,
    /// and while `s_i == d_i` the next draft's KV column is valid so
    /// sampling continues from `o_i` — all without another round-trip.
    /// The first non-matching sample ends the round: positions `0..i`
    /// commit, the rejected suffix is abandoned (the servers shed it via
    /// implicit rollback on the next frame), and `s_i` becomes the next
    /// round's anchor. If every draft matches, one bonus token is
    /// sampled from `o_q`. The very first round has no anchor yet and
    /// just samples from the prefill output.
    fn run_verify_round(&mut self) -> Result<()> {
        let spec = self.opts.speculation.clone().expect("spec_active checked");
        let remaining = self.opts.max_new - self.steps;
        if self.produced[0].is_empty() {
            // round 0: sample the first token from the prefill output;
            // its decode step is deferred into the next round's anchor
            let logits = self.head.lm_head(&self.last)?;
            let t = self.sampler.sample(&logits)[0];
            self.spec_buf.push_back(PendingTok {
                token: t,
                accepted: false,
                logits: self.opts.want_logits.then_some(logits),
                hidden: self.opts.want_hidden.then(|| self.last.clone()),
            });
            return Ok(());
        }
        let anchor = *self.produced[0].last().expect("non-empty");
        let mut history = self.prompt0.clone();
        history.extend_from_slice(&self.produced[0]);
        // a round emits at most q+1 tokens; stay within max_new and the
        // wire's per-frame position ceiling
        let q_cap = spec
            .max_k
            .min(crate::draft::MAX_SPEC_K - 1)
            .min(remaining.saturating_sub(1));
        let mut drafts = if q_cap == 0 {
            Vec::new()
        } else {
            spec.draft.propose(&history, q_cap)
        };
        drafts.truncate(q_cap);
        let q = drafts.len();
        let m = q + 1;
        let hd = self.head.hidden;
        // embed anchor + drafts position-by-position (the embedding is
        // positionless, so per-token embeds concatenate bitwise equal to
        // a width-m embed — and only width-1 is compiled for decode)
        let mut payload = vec![0f32; m * hd];
        for (j, &t) in std::iter::once(&anchor).chain(drafts.iter()).enumerate() {
            let e = self.head.embed(&Tensor::from_i32(&[1, 1], &[t]))?;
            payload[j * hd..(j + 1) * hd].copy_from_slice(e.as_f32());
        }
        let session = self
            .session
            .as_mut()
            .ok_or_else(|| Error::Protocol("stream already closed".into()))?;
        let out = session.propose_verify(Tensor::from_f32(&[1, m, hd], &payload))?;
        let of = out.as_f32();
        let mut accepted = 0usize;
        let mut emitted = 0usize;
        for j in 0..m {
            // o_j = the chain's output after the token at position j —
            // the exact hidden state plain decoding would have produced
            let o_t = Tensor::from_f32(&[1, hd], &of[j * hd..(j + 1) * hd]);
            let logits = self.head.lm_head(&o_t)?;
            let s = self.sampler.sample(&logits)[0];
            let draft_hit = j < q && s == drafts[j];
            self.spec_buf.push_back(PendingTok {
                token: s,
                accepted: draft_hit,
                logits: self.opts.want_logits.then_some(logits),
                hidden: self.opts.want_hidden.then(|| o_t.clone()),
            });
            emitted += 1;
            self.last = o_t;
            if draft_hit {
                accepted += 1;
            } else {
                // mismatch (the draft's KV column is wrong) or the
                // all-accepted bonus sample: either way the round ends
                break;
            }
        }
        let session = self.session.as_mut().expect("checked above");
        session.commit_verify(emitted)?;
        self.spec_stats.rounds += 1;
        self.spec_stats.proposed += q as u64;
        self.spec_stats.accepted += accepted as u64;
        Ok(())
    }

    /// Tokens produced so far, [B][steps].
    pub fn tokens(&self) -> &[Vec<i32>] {
        &self.produced
    }

    pub fn steps(&self) -> usize {
        self.steps
    }

    /// The live sampler (policy + advancing RNG) — its
    /// [`SamplerState::rng_state`] is part of a resumption snapshot.
    pub fn sampler_state(&self) -> &SamplerState {
        &self.sampler
    }

    /// Which rows already stopped (`true` = exited the batch early).
    pub fn rows_done(&self) -> &[bool] {
        &self.row_done
    }

    /// Speculative-decoding counters so far (all zero when speculation
    /// is off).
    pub fn spec_stats(&self) -> SpecStats {
        self.spec_stats
    }

    /// Recoveries performed so far (final total once the stream ends).
    pub fn recoveries(&self) -> usize {
        self.session.as_ref().map(|s| s.recoveries()).unwrap_or(self.recoveries)
    }

    /// Why the stream ended (`None` while still producing).
    pub fn finish_reason(&self) -> Option<FinishReason> {
        self.finish
    }

    /// Drain the remaining tokens and return the aggregate result — the
    /// batch endpoint's code path.
    pub fn finish(mut self) -> Result<GenerationResult> {
        while self.next_step()?.is_some() {}
        Ok(GenerationResult {
            tokens: std::mem::take(&mut self.produced),
            steps: self.steps,
            recoveries: self.recoveries(),
            wall: self.started.elapsed(),
            finish: self.finish.unwrap_or(FinishReason::Length),
            spec: self.spec_stats,
        })
    }

    fn close_session(&mut self) {
        if let Some(session) = self.session.take() {
            self.recoveries = session.recoveries();
            session.close();
        }
    }
}

impl<'a, C: ChainClient> Drop for GenerationStream<'a, C> {
    fn drop(&mut self) {
        // an abandoned stream (client hung up mid-generation) must not
        // leak per-server sessions
        self.close_session();
    }
}

/// Pull position `pos` out of a [B,S,H] tensor -> flat [B*H].
fn extract_positions(h: &Tensor, pos: usize) -> Vec<f32> {
    extract_row_positions(h, &vec![pos + 1; h.shape[0]])
}

/// Pull each row's LAST VALID position (`lens[i] - 1`) out of a [B,S,H]
/// tensor -> flat [B*H] — the ragged twin of [`extract_positions`]: a
/// multi-prompt batch reads each row's hidden state at that row's own
/// prompt end, not at a shared offset.
fn extract_row_positions(h: &Tensor, lens: &[usize]) -> Vec<f32> {
    let (b, s, hd) = (h.shape[0], h.shape[1], h.shape[2]);
    assert_eq!(b, lens.len());
    let src = h.as_f32();
    let mut out = Vec::with_capacity(b * hd);
    for (i, &len) in lens.iter().enumerate() {
        assert!(len >= 1 && len <= s);
        let off = (i * s + (len - 1)) * hd;
        out.extend_from_slice(&src[off..off + hd]);
    }
    out
}

/// Longest common leading token run across rows (the shared template a
/// multi-prompt session declares as its prefix identity).
fn common_prefix(rows: &[Vec<i32>]) -> Vec<i32> {
    let Some(first) = rows.first() else {
        return Vec::new();
    };
    let mut n = first.len();
    for row in &rows[1..] {
        n = n
            .min(row.len())
            .min(first.iter().zip(row.iter()).take_while(|(a, b)| a == b).count());
    }
    first[..n].to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_argmax() {
        let logits = Tensor::from_f32(&[2, 4], &[0.1, 0.9, 0.0, 0.2, 5.0, 1.0, 2.0, 3.0]);
        assert_eq!(Sampler::Greedy.sample(&logits), vec![1, 0]);
    }

    #[test]
    fn topk_respects_k() {
        let logits = Tensor::from_f32(&[1, 5], &[10.0, 9.0, -50.0, -50.0, -50.0]);
        let s = Sampler::TopK { k: 2, temperature: 1.0, seed: 1 };
        for trial in 0..20 {
            let s = Sampler::TopK { k: 2, temperature: 1.0, seed: trial };
            let t = s.sample(&logits)[0];
            assert!(t == 0 || t == 1, "token {t} outside top-2");
        }
        let _ = s;
    }

    #[test]
    fn topk_deterministic_per_seed() {
        let logits = Tensor::from_f32(&[1, 8], &[1.0, 2.0, 3.0, 4.0, 3.5, 2.5, 1.5, 0.5]);
        let a = Sampler::TopK { k: 4, temperature: 0.8, seed: 7 }.sample(&logits);
        let b = Sampler::TopK { k: 4, temperature: 0.8, seed: 7 }.sample(&logits);
        assert_eq!(a, b);
    }

    /// Property: top_p = 1.0 is exactly temperature-softmax sampling
    /// over the full vocabulary (same seed ⇒ same token as an
    /// independently written inverse-CDF reference).
    #[test]
    fn prop_topp_one_is_full_softmax() {
        let mut rng = crate::config::Rng::new(0xA11);
        for trial in 0..50u64 {
            let v = 4 + rng.usize_below(60);
            let row: Vec<f32> = (0..v).map(|_| (rng.f64() as f32 - 0.5) * 8.0).collect();
            let temperature = 0.3 + rng.f64() as f32 * 1.4;
            let logits = Tensor::from_f32(&[1, v], &row);
            let got = Sampler::TopP { p: 1.0, temperature, seed: trial }.sample(&logits)[0];

            // reference: descending sort, softmax, inverse-CDF — written
            // independently of the production cumulative-cut logic
            let mut idx: Vec<usize> = (0..v).collect();
            idx.sort_by(|&a, &b| row[b].total_cmp(&row[a]));
            let t = temperature.max(1e-4);
            let mx = row[idx[0]];
            let w: Vec<f64> = idx.iter().map(|&i| (((row[i] - mx) / t) as f64).exp()).collect();
            let total: f64 = w.iter().sum();
            let mut r = crate::config::Rng::new(trial).f64() * total;
            let mut want = idx[v - 1];
            for (j, wj) in w.iter().enumerate() {
                r -= wj;
                if r <= 0.0 {
                    want = idx[j];
                    break;
                }
            }
            assert_eq!(got, want as i32, "trial {trial}: top_p=1.0 != full softmax");
        }
    }

    /// Property: top_p -> 0 degenerates to greedy (argmax), for any
    /// temperature and seed.
    #[test]
    fn prop_topp_zero_is_greedy() {
        let mut rng = crate::config::Rng::new(0xB22);
        for trial in 0..50u64 {
            let v = 4 + rng.usize_below(60);
            let row: Vec<f32> = (0..v).map(|_| (rng.f64() as f32 - 0.5) * 8.0).collect();
            let logits = Tensor::from_f32(&[1, v], &row);
            let greedy = Sampler::Greedy.sample(&logits)[0];
            let temperature = 0.2 + rng.f64() as f32 * 2.0;
            let got = Sampler::TopP { p: 0.0, temperature, seed: trial }.sample(&logits)[0];
            assert_eq!(got, greedy, "trial {trial}: top_p=0 != greedy");
        }
    }

    /// Property: a fixed seed produces a bitwise-identical *sequence* of
    /// samples (the RNG advances across steps — two runs stay in
    /// lockstep), and different seeds eventually diverge.
    #[test]
    fn prop_topp_fixed_seed_sequences_identical() {
        let mut rng = crate::config::Rng::new(0xC33);
        let rows: Vec<Vec<f32>> = (0..32)
            .map(|_| (0..24).map(|_| (rng.f64() as f32 - 0.5) * 6.0).collect())
            .collect();
        let run = |seed: u64| -> Vec<i32> {
            let mut st = Sampler::TopP { p: 0.9, temperature: 0.8, seed }.start();
            rows.iter()
                .map(|row| st.sample(&Tensor::from_f32(&[1, row.len()], row))[0])
                .collect()
        };
        assert_eq!(run(7), run(7), "same seed must be bitwise identical");
        // the RNG must actually advance: a constant-per-step RNG would
        // produce the same token whenever the same row repeats
        let row = vec![1.0f32, 0.9, 0.8, 0.7, 0.6, 0.5, 0.4, 0.3];
        let mut st = Sampler::TopP { p: 1.0, temperature: 1.5, seed: 3 }.start();
        let picks: Vec<i32> = (0..64)
            .map(|_| st.sample(&Tensor::from_f32(&[1, row.len()], &row))[0])
            .collect();
        let first = picks[0];
        assert!(picks.iter().any(|&t| t != first), "RNG never advanced across steps");
        assert_ne!(run(1), run(2), "different seeds should diverge");
    }

    #[test]
    fn topp_respects_nucleus() {
        // two dominant tokens hold ~all the mass: p=0.5 must never pick
        // the tail
        let logits = Tensor::from_f32(&[1, 5], &[10.0, 9.5, -40.0, -40.0, -40.0]);
        for seed in 0..30 {
            let t = Sampler::TopP { p: 0.5, temperature: 1.0, seed }.sample(&logits)[0];
            assert!(t == 0 || t == 1, "token {t} outside the nucleus");
        }
    }

    #[test]
    fn embed_width_parsing_and_derivation() {
        let names = ["embed_b1_s1", "embed_b1_s128", "embed_b4_s64", "embed_b8_s128", "lm_head_b1"];
        assert_eq!(parse_embed_widths(names.iter().copied(), 1), vec![128]);
        assert_eq!(parse_embed_widths(names.iter().copied(), 4), vec![64]);
        assert_eq!(parse_embed_widths(names.iter().copied(), 2), Vec::<usize>::new());
    }

    #[test]
    fn extract_positions_layout() {
        // B=2,S=3,H=2
        let h = Tensor::from_f32(
            &[2, 3, 2],
            &[0., 1., 10., 11., 20., 21., 100., 101., 110., 111., 120., 121.],
        );
        assert_eq!(extract_positions(&h, 1), vec![10., 11., 110., 111.]);
        assert_eq!(extract_positions(&h, 2), vec![20., 21., 120., 121.]);
        // ragged: row 0 ends at position 0, row 1 at position 2
        assert_eq!(extract_row_positions(&h, &[1, 3]), vec![0., 1., 120., 121.]);
        assert_eq!(extract_row_positions(&h, &[2, 1]), vec![10., 11., 100., 101.]);
    }

    #[test]
    fn common_prefix_of_rows() {
        let rows = vec![vec![1, 2, 3, 4], vec![1, 2, 9], vec![1, 2, 3]];
        assert_eq!(common_prefix(&rows), vec![1, 2]);
        assert_eq!(common_prefix(&[vec![5, 6], vec![5, 6]]), vec![5, 6]);
        assert_eq!(common_prefix(&[vec![1], vec![2]]), Vec::<i32>::new());
        assert_eq!(common_prefix(&[]), Vec::<i32>::new());
        // one row: the whole row is the common prefix
        assert_eq!(common_prefix(&[vec![7, 8, 9]]), vec![7, 8, 9]);
    }
}
