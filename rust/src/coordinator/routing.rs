//! Client-side routing (§3.2): find the chain of servers that runs the
//! model in the least time.
//!
//! "clients have to ping nearby servers to measure latency and then find
//! the path with minimal time via beam search."
//!
//! The graph: a path must cover blocks `0..n_blocks` left to right; each
//! server hosts a contiguous span, so a chain is a sequence of servers
//! whose spans tile the range. Hop cost = message time (client→server or
//! server→server over the slower of the two links) + the server's
//! predicted span compute time; the final hop returns to the client.
//! Beam search keeps the `beam_width` cheapest partial chains per
//! frontier block.

use std::collections::HashMap;

/// What the client knows about one server (from Pong probes + DHT).
#[derive(Debug, Clone)]
pub struct ServerView {
    /// Stable identity (DHT id).
    pub id: crate::dht::NodeId,
    /// Hosted span [start, end).
    pub start: usize,
    pub end: usize,
    /// Measured one-way latency client<->server, seconds.
    pub latency_s: f64,
    /// Link bandwidth estimate, bits/s.
    pub bandwidth_bps: f64,
    /// Predicted seconds to process one request over the full span.
    pub span_compute_s: f64,
    /// Current queue depth (multi-client contention signal).
    pub queue_depth: u32,
    /// Fraction of the server's KV-cache pool still free, in [0, 1]
    /// (from Pong / DHT announcements). 1.0 when unknown — legacy
    /// servers never get penalized for data they don't report.
    pub free_ratio: f64,
    /// Fingerprints of the server's hottest cached prompt prefixes (v3
    /// DHT announcements; empty when unknown). Used for cache-aware
    /// sticky routing: a server already holding the session's prefix
    /// skips the prefill recompute and charges only marginal KV pages.
    pub prefix_fps: Vec<u64>,
    /// Announced p50 step latency over the full span, microseconds (v4
    /// DHT telemetry / `PongV2`); 0 when unknown. When present it is a
    /// better full-span time estimate than the throughput-derived
    /// `span_compute_s` — and it is the same number `petals top` shows,
    /// so routing and the operator dashboard agree.
    pub p50_step_us: u32,
    /// Client-side EWMA of *measured* per-hop step seconds
    /// ([`crate::coordinator::throughput::MeasuredHops`]); `None` until
    /// this client has stepped through the server.
    pub measured_step_s: Option<f64>,
    /// Seconds since the last measurement sample (staleness of
    /// `measured_step_s`).
    pub measured_age_s: f64,
}

impl ServerView {
    /// Predicted time for a message of `bytes` to reach this server.
    fn msg_time(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 * 8.0 / self.bandwidth_bps
    }

    /// Best estimate of one step's seconds over the full span: the
    /// client's own measurement when fresh, decaying back to the
    /// announced value (p50 telemetry, else throughput-derived
    /// `span_compute_s`) with half-life `half_life_s`. Minimizing the
    /// per-step sum along a chain is maximizing estimated end-to-end
    /// tokens/s.
    pub fn effective_step_s(&self, half_life_s: f64) -> f64 {
        let announced =
            if self.p50_step_us > 0 { self.p50_step_us as f64 * 1e-6 } else { self.span_compute_s };
        match self.measured_step_s {
            Some(m) => {
                let w = if half_life_s > 0.0 {
                    0.5f64.powf(self.measured_age_s.max(0.0) / half_life_s)
                } else {
                    0.0
                };
                w * m + (1.0 - w) * announced
            }
            None => announced,
        }
    }
}

/// Inputs to chain search.
#[derive(Debug, Clone)]
pub struct RouteQuery {
    pub n_blocks: usize,
    /// Hidden-state bytes per hop message.
    pub msg_bytes: u64,
    pub beam_width: usize,
    /// Extra seconds charged per queued request at a server (models
    /// waiting behind other clients).
    pub queue_penalty_s: f64,
    /// Extra seconds charged proportionally to a server's KV-pool
    /// occupancy (`(1 - free_ratio) * pool_penalty_s`): steers sessions
    /// toward servers that will not reject admission.
    pub pool_penalty_s: f64,
    /// Fingerprint of this session's prompt prefix
    /// ([`crate::server::prefixcache::fingerprint`]); `None` disables
    /// cache-aware routing.
    pub prefix_fp: Option<u64>,
    /// Extra seconds charged to servers that do *not* advertise
    /// `prefix_fp` when it is set — the sticky-routing lever that lands
    /// template traffic on servers already holding the prefix (which
    /// skip the prefill recompute and charge only marginal pages).
    /// Servers with no announcement are penalized uniformly, so relative
    /// ranking among legacy servers is unchanged.
    pub prefix_miss_penalty_s: f64,
    /// Half-life, seconds, of the decay from a *measured* per-hop step
    /// time back to the announced one
    /// ([`ServerView::effective_step_s`]). 0 disables measurements
    /// entirely (announced values only).
    pub measured_half_life_s: f64,
}

impl Default for RouteQuery {
    fn default() -> Self {
        RouteQuery {
            n_blocks: 0,
            msg_bytes: 0,
            beam_width: 8,
            queue_penalty_s: 0.05,
            pool_penalty_s: 0.05,
            prefix_fp: None,
            prefix_miss_penalty_s: 0.05,
            measured_half_life_s: 30.0,
        }
    }
}

/// One hop of a selected chain.
#[derive(Debug, Clone, PartialEq)]
pub struct ChainHop {
    pub server: crate::dht::NodeId,
    pub start: usize,
    pub end: usize,
}

#[derive(Clone)]
struct Partial {
    cost: f64,
    hops: Vec<(usize, usize)>, // (server index, entry block)
}

/// Beam search for the fastest chain covering all blocks.
/// Returns hops + predicted per-step time, or None if some block has no
/// live server.
pub fn find_chain(servers: &[ServerView], q: &RouteQuery) -> Option<(Vec<ChainHop>, f64)> {
    if q.n_blocks == 0 {
        return Some((vec![], 0.0));
    }
    // candidates by covered block: a client may enter a server at any
    // block inside its hosted span (it requests a sub-range), so spans
    // that overlap after rebalancing still stitch into chains
    let mut by_block: HashMap<usize, Vec<usize>> = HashMap::new();
    for (i, s) in servers.iter().enumerate() {
        for b in s.start..s.end {
            by_block.entry(b).or_default().push(i);
        }
    }
    // frontier: block index -> beam of partials
    let mut beams: HashMap<usize, Vec<Partial>> = HashMap::new();
    beams.insert(0, vec![Partial { cost: 0.0, hops: vec![] }]);
    // process frontiers in block order
    for block in 0..q.n_blocks {
        let Some(partials) = beams.remove(&block) else {
            continue;
        };
        let Some(cands) = by_block.get(&block) else {
            continue;
        };
        for p in &partials {
            for &ci in cands {
                let s = &servers[ci];
                let next = s.end.min(q.n_blocks);
                if next <= block {
                    continue;
                }
                // entry hop: from client (first) or previous server; we
                // approximate server->server latency with the entered
                // server's client latency (the client measured only its
                // own pings — same approximation the paper's client makes
                // before the first real step).
                let hop_in = s.msg_time(q.msg_bytes);
                let queue = s.queue_depth as f64 * q.queue_penalty_s;
                let pool = (1.0 - s.free_ratio.clamp(0.0, 1.0)) * q.pool_penalty_s;
                let prefix = match q.prefix_fp {
                    Some(fp) if !s.prefix_fps.contains(&fp) => q.prefix_miss_penalty_s,
                    _ => 0.0,
                };
                // compute prorated to the sub-span actually used; the
                // step-time estimate blends this client's own measured
                // hop clocks with announced telemetry
                let frac = (next - block) as f64 / (s.end - s.start) as f64;
                let step = s.effective_step_s(q.measured_half_life_s);
                let cost = p.cost + hop_in + step * frac + queue + pool + prefix;
                let mut hops = p.hops.clone();
                hops.push((ci, block));
                let beam = beams.entry(next).or_default();
                beam.push(Partial { cost, hops });
                beam.sort_by(|a, b| a.cost.total_cmp(&b.cost));
                beam.truncate(q.beam_width);
            }
        }
    }
    let done = beams.remove(&q.n_blocks)?;
    // the return leg to the client depends on the LAST hop's link, so it
    // must be added before choosing the winner
    let (best, total) = done
        .into_iter()
        .filter_map(|p| {
            let last = &servers[p.hops.last()?.0];
            let total = p.cost + last.msg_time(q.msg_bytes);
            Some((p, total))
        })
        .min_by(|a, b| a.1.total_cmp(&b.1))?;
    let hops = best
        .hops
        .iter()
        .map(|&(i, entry)| ChainHop {
            server: servers[i].id,
            start: entry,
            end: servers[i].end.min(q.n_blocks),
        })
        .collect();
    Some((hops, total))
}

/// Find a chain covering only `from..to` (used to replace a failed
/// server mid-session, §3.2 failure recovery).
pub fn find_subchain(
    servers: &[ServerView],
    q: &RouteQuery,
    from: usize,
    to: usize,
) -> Option<Vec<ChainHop>> {
    // re-index the world so `from..to` looks like `0..(to-from)`
    let shifted: Vec<ServerView> = servers
        .iter()
        .filter(|s| s.start <= from && s.end > from || (s.start > from && s.start < to))
        .map(|s| {
            let mut c = s.clone();
            c.start = c.start.max(from) - from;
            c.end = c.end.min(to) - from;
            c
        })
        .collect();
    let sub_q = RouteQuery { n_blocks: to - from, ..q.clone() };
    let (hops, _) = find_chain(&shifted, &sub_q)?;
    Some(
        hops.into_iter()
            .map(|h| ChainHop { server: h.server, start: h.start + from, end: h.end + from }, )
            .collect(),
    )
}

/// Validate that hops tile `0..n_blocks` exactly.
pub fn chain_is_valid(hops: &[ChainHop], n_blocks: usize) -> bool {
    let mut at = 0;
    for h in hops {
        if h.start != at || h.end <= h.start {
            return false;
        }
        at = h.end;
    }
    at == n_blocks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dht::NodeId;

    fn sv(name: &str, start: usize, end: usize, lat: f64, comp: f64) -> ServerView {
        ServerView {
            id: NodeId::from_name(name),
            start,
            end,
            latency_s: lat,
            bandwidth_bps: 1e9,
            span_compute_s: comp,
            queue_depth: 0,
            free_ratio: 1.0,
            prefix_fps: vec![],
            p50_step_us: 0,
            measured_step_s: None,
            measured_age_s: 0.0,
        }
    }

    fn q(n: usize) -> RouteQuery {
        RouteQuery { n_blocks: n, msg_bytes: 2048, ..Default::default() }
    }

    #[test]
    fn single_server_chain() {
        let servers = [sv("a", 0, 8, 0.01, 0.1)];
        let (hops, t) = find_chain(&servers, &q(8)).unwrap();
        assert_eq!(hops.len(), 1);
        assert!(chain_is_valid(&hops, 8));
        // in + compute + out
        assert!((t - (0.01 + 0.1 + 0.01 + 2.0 * 2048.0 * 8.0 / 1e9)).abs() < 1e-9);
    }

    #[test]
    fn prefers_fast_replica() {
        let servers = [
            sv("slow", 0, 8, 0.10, 0.5),
            sv("fast", 0, 8, 0.01, 0.1),
        ];
        let (hops, _) = find_chain(&servers, &q(8)).unwrap();
        assert_eq!(hops[0].server, NodeId::from_name("fast"));
    }

    #[test]
    fn stitches_partial_spans() {
        let servers = [
            sv("a", 0, 3, 0.01, 0.1),
            sv("b", 3, 6, 0.01, 0.1),
            sv("c", 6, 8, 0.01, 0.1),
        ];
        let (hops, _) = find_chain(&servers, &q(8)).unwrap();
        assert_eq!(hops.len(), 3);
        assert!(chain_is_valid(&hops, 8));
    }

    #[test]
    fn fewer_hops_beat_many_when_latency_dominates() {
        // one big server vs 4 small ones with the same total compute:
        // high per-hop latency should favor the single server
        let servers = [
            sv("big", 0, 8, 0.10, 0.4),
            sv("s1", 0, 2, 0.10, 0.1),
            sv("s2", 2, 4, 0.10, 0.1),
            sv("s3", 4, 6, 0.10, 0.1),
            sv("s4", 6, 8, 0.10, 0.1),
        ];
        let (hops, _) = find_chain(&servers, &q(8)).unwrap();
        assert_eq!(hops.len(), 1, "latency-dominated -> prefer 1 hop");
    }

    #[test]
    fn many_hops_beat_one_when_compute_dominates() {
        let servers = [
            sv("big", 0, 8, 0.001, 1.6), // slow device
            sv("s1", 0, 4, 0.001, 0.2),
            sv("s2", 4, 8, 0.001, 0.2),
        ];
        let (hops, _) = find_chain(&servers, &q(8)).unwrap();
        assert_eq!(hops.len(), 2);
    }

    #[test]
    fn no_route_when_gap() {
        let servers = [sv("a", 0, 3, 0.01, 0.1), sv("c", 5, 8, 0.01, 0.1)];
        assert!(find_chain(&servers, &q(8)).is_none());
    }

    #[test]
    fn queue_depth_steers_away() {
        let mut busy = sv("busy", 0, 8, 0.01, 0.1);
        busy.queue_depth = 10;
        let idle = sv("idle", 0, 8, 0.02, 0.12);
        let (hops, _) = find_chain(&[busy, idle], &q(8)).unwrap();
        assert_eq!(hops[0].server, NodeId::from_name("idle"));
    }

    #[test]
    fn pool_pressure_steers_away() {
        // a nearly-full KV pool costs more than a slightly slower link,
        // so new sessions land where admission will succeed
        let mut full = sv("full", 0, 8, 0.010, 0.1);
        full.free_ratio = 0.02;
        let roomy = sv("roomy", 0, 8, 0.012, 0.1);
        let (hops, _) = find_chain(&[full.clone(), roomy], &q(8)).unwrap();
        assert_eq!(hops[0].server, NodeId::from_name("roomy"));
        // with the penalty disabled the faster-but-full server wins again
        let mut q0 = q(8);
        q0.pool_penalty_s = 0.0;
        let roomy = sv("roomy", 0, 8, 0.012, 0.1);
        let (hops, _) = find_chain(&[full, roomy], &q0).unwrap();
        assert_eq!(hops[0].server, NodeId::from_name("full"));
    }

    #[test]
    fn prefix_holder_wins_sticky_routing() {
        // a slightly slower server that already caches the session's
        // prefix beats a faster cold one (it skips the prefill recompute)
        let fp = 0xfeed_beefu64;
        let mut warm = sv("warm", 0, 8, 0.012, 0.1);
        warm.prefix_fps = vec![1, fp, 2];
        let cold = sv("cold", 0, 8, 0.010, 0.1);
        let mut query = q(8);
        query.prefix_fp = Some(fp);
        let (hops, _) = find_chain(&[warm.clone(), cold], &query).unwrap();
        assert_eq!(hops[0].server, NodeId::from_name("warm"));
        // without the fingerprint the faster server wins again
        query.prefix_fp = None;
        let cold = sv("cold", 0, 8, 0.010, 0.1);
        let (hops, _) = find_chain(&[warm, cold], &query).unwrap();
        assert_eq!(hops[0].server, NodeId::from_name("cold"));
        // legacy servers (no fps) are penalized uniformly: ranking kept
        let mut query = q(8);
        query.prefix_fp = Some(fp);
        let a = sv("a", 0, 8, 0.010, 0.1);
        let b = sv("b", 0, 8, 0.020, 0.1);
        let (hops, _) = find_chain(&[a, b], &query).unwrap();
        assert_eq!(hops[0].server, NodeId::from_name("a"));
    }

    #[test]
    fn p50_telemetry_overrides_throughput_estimate() {
        // same announced span_compute, but the gossiped p50 step latency
        // (the number `petals top` shows) says "slow" is 10x slower —
        // routing must consult it and agree with the dashboard
        let mut slow = sv("slow", 0, 8, 0.01, 0.1);
        slow.p50_step_us = 1_000_000; // 1 s
        let mut fast = sv("fast", 0, 8, 0.02, 0.1);
        fast.p50_step_us = 100_000; // 0.1 s
        let (hops, _) = find_chain(&[slow, fast], &q(8)).unwrap();
        assert_eq!(hops[0].server, NodeId::from_name("fast"));
    }

    #[test]
    fn fresh_measurement_beats_announced_rate() {
        // "adv" announces a great rate but this client MEASURED it slow;
        // "honest" announces slower but measures as announced. With a
        // fresh measurement the honest server must win; with the
        // measurement decayed far past its half-life, announced values
        // take over again and "adv" wins.
        let mk = |age: f64| {
            let mut adv = sv("adv", 0, 8, 0.01, 0.05);
            adv.measured_step_s = Some(0.8);
            adv.measured_age_s = age;
            let mut honest = sv("honest", 0, 8, 0.01, 0.2);
            honest.measured_step_s = Some(0.2);
            honest.measured_age_s = age;
            [adv, honest]
        };
        let (hops, _) = find_chain(&mk(0.0), &q(8)).unwrap();
        assert_eq!(hops[0].server, NodeId::from_name("honest"), "fresh measurement must win");
        let (hops, _) = find_chain(&mk(10_000.0), &q(8)).unwrap();
        assert_eq!(hops[0].server, NodeId::from_name("adv"), "stale must decay to announced");
    }

    #[test]
    fn effective_step_blend_decays_toward_announced() {
        let mut v = sv("v", 0, 8, 0.01, 0.4);
        assert!((v.effective_step_s(30.0) - 0.4).abs() < 1e-12, "no data -> span_compute_s");
        v.p50_step_us = 200_000;
        assert!((v.effective_step_s(30.0) - 0.2).abs() < 1e-12, "p50 replaces derived estimate");
        v.measured_step_s = Some(1.0);
        v.measured_age_s = 0.0;
        assert!((v.effective_step_s(30.0) - 1.0).abs() < 1e-12, "age 0 -> all measured");
        v.measured_age_s = 30.0;
        let half = v.effective_step_s(30.0);
        assert!((half - 0.6).abs() < 1e-12, "one half-life -> midpoint, got {half}");
        v.measured_age_s = 1e9;
        assert!((v.effective_step_s(30.0) - 0.2).abs() < 1e-9, "ancient -> announced");
        // half-life 0 disables measurements entirely
        v.measured_age_s = 0.0;
        assert!((v.effective_step_s(0.0) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn subchain_replaces_failed_span() {
        let servers = [
            sv("a", 0, 3, 0.01, 0.1),
            sv("b2", 3, 6, 0.02, 0.2), // replacement candidate
            sv("c", 6, 8, 0.01, 0.1),
            sv("wide", 2, 7, 0.03, 0.3),
        ];
        let hops = find_subchain(&servers, &q(8), 3, 6).unwrap();
        assert_eq!(hops.len(), 1);
        assert_eq!(hops[0].start, 3);
        assert_eq!(hops[0].end, 6);
        assert_eq!(hops[0].server, NodeId::from_name("b2"));
    }

    #[test]
    fn prop_chain_always_valid_and_cost_positive() {
        let mut rng = crate::config::Rng::new(0x207);
        for _ in 0..300 {
            let n = 1 + rng.usize_below(24);
            let mut servers = Vec::new();
            for i in 0..1 + rng.usize_below(10) {
                let start = rng.usize_below(n);
                let end = (start + 1 + rng.usize_below(n - start)).min(n);
                servers.push(sv(
                    &format!("s{i}"),
                    start,
                    end,
                    rng.range_f64(0.001, 0.2),
                    rng.range_f64(0.01, 1.0),
                ));
            }
            if let Some((hops, t)) = find_chain(&servers, &q(n)) {
                assert!(chain_is_valid(&hops, n), "hops {hops:?} n={n}");
                assert!(t > 0.0);
            }
        }
    }

    #[test]
    fn prop_beam_finds_optimum_on_small_instances() {
        // exhaustive check: beam width >= candidate count must match
        // brute-force optimal cost on tiny instances
        let mut rng = crate::config::Rng::new(0x208);
        for _ in 0..60 {
            let n = 1 + rng.usize_below(6);
            let mut servers = Vec::new();
            for i in 0..1 + rng.usize_below(6) {
                let start = rng.usize_below(n);
                let end = (start + 1 + rng.usize_below(n - start)).min(n);
                let mut s = sv(
                    &format!("s{i}"),
                    start,
                    end,
                    rng.range_f64(0.001, 0.1),
                    rng.range_f64(0.01, 0.5),
                );
                // randomize the telemetry/measurement fields too, so the
                // brute-force cost model can never drift out of sync
                // with the beam's on the measured-throughput terms
                if rng.usize_below(2) == 0 {
                    s.p50_step_us = 1 + rng.usize_below(400_000) as u32;
                }
                if rng.usize_below(2) == 0 {
                    s.measured_step_s = Some(rng.range_f64(0.01, 0.5));
                    s.measured_age_s = rng.range_f64(0.0, 120.0);
                }
                servers.push(s);
            }
            let mut query = q(n);
            query.beam_width = 64;
            let got = find_chain(&servers, &query);
            let want = brute_force(&servers, &query);
            match (got, want) {
                (None, None) => {}
                (Some((_, tg)), Some(tw)) => {
                    assert!((tg - tw).abs() < 1e-9, "beam {tg} vs brute {tw}")
                }
                (g, w) => panic!("beam {g:?} vs brute {w:?}"),
            }
        }
    }

    fn brute_force(servers: &[ServerView], q: &RouteQuery) -> Option<f64> {
        fn rec(servers: &[ServerView], q: &RouteQuery, at: usize, cost: f64, best: &mut Option<f64>) {
            if at == q.n_blocks {
                return; // caller adds return leg
            }
            for s in servers {
                if s.start <= at && s.end > at {
                    let next = s.end.min(q.n_blocks);
                    let frac = (next - at) as f64 / (s.end - s.start) as f64;
                    let c = cost
                        + s.msg_time(q.msg_bytes)
                        + s.effective_step_s(q.measured_half_life_s) * frac
                        + s.queue_depth as f64 * q.queue_penalty_s
                        + (1.0 - s.free_ratio.clamp(0.0, 1.0)) * q.pool_penalty_s
                        + match q.prefix_fp {
                            Some(fp) if !s.prefix_fps.contains(&fp) => q.prefix_miss_penalty_s,
                            _ => 0.0,
                        };
                    if next == q.n_blocks {
                        let total = c + s.msg_time(q.msg_bytes);
                        if best.map(|b| total < b).unwrap_or(true) {
                            *best = Some(total);
                        }
                    } else {
                        rec(servers, q, next, c, best);
                    }
                }
            }
        }
        let mut best = None;
        rec(servers, q, 0, 0.0, &mut best);
        best
    }
}
