//! Fault-tolerant inference sessions (§2.1, §3.2).
//!
//! "While the session is active, servers store attention keys and values
//! from past client inputs [...] Clients also store past inputs to each
//! server so that if any server fails or goes offline, another one can
//! quickly take its place. [...] During inference, the client sends all
//! previous inputs to the replacement server, so that it has the same
//! attention keys and values."
//!
//! [`InferenceSession`] is generic over [`ChainClient`], so the same
//! recovery logic is exercised by the in-process cluster (tests,
//! quickstart), the TCP swarm (examples), and failure-injection tests.

use crate::coordinator::routing::{self, ChainHop, RouteQuery, ServerView};
use crate::dht::NodeId;
use crate::error::{Error, Result};
use crate::model::tensor::Tensor;
use crate::trace::{HopTrace, StepBreakdown, TraceContext};

/// Reply to a latency probe, plus client-measured link stats.
#[derive(Debug, Clone)]
pub struct PongInfo {
    pub start: usize,
    pub end: usize,
    pub throughput: f32,
    pub queue_depth: u32,
    /// KV-pool pages free / total (v2 Pong; 0/0 when unknown).
    pub free_pages: u32,
    pub total_pages: u32,
    /// Max sessions the server fuses per decode step.
    pub batch_width: u32,
    pub latency_s: f64,
    pub bandwidth_bps: f64,
}

/// Everything a session needs from the swarm. Implementations: the
/// in-process cluster (`server::local`), the TCP swarm (`server::service`),
/// and the simulator.
pub trait ChainClient {
    /// Current world view: DHT snapshot + pings (§3.2 client routing).
    fn discover(&self) -> Vec<ServerView>;
    fn open_session(
        &self,
        server: NodeId,
        session: u64,
        batch: usize,
        prefix_len: usize,
        max_new: usize,
    ) -> Result<()>;
    /// Open carrying the session's prefix token ids + prefill width
    /// (wire v3), so the server can attach cached shared-prefix KV pages
    /// and skip recomputing the prefix. The default forwards to the
    /// legacy [`Self::open_session`], so transports and test fakes that
    /// predate prefix sharing keep working unchanged.
    #[allow(clippy::too_many_arguments)]
    fn open_session_prefixed(
        &self,
        server: NodeId,
        session: u64,
        batch: usize,
        prefix_len: usize,
        max_new: usize,
        _prefix_tokens: &[i32],
        _prefill_width: usize,
    ) -> Result<()> {
        self.open_session(server, session, batch, prefix_len, max_new)
    }
    /// Run the (padded) prefix through the server's span, filling its KV
    /// caches; returns the hidden states for the next span.
    fn prefill(&self, server: NodeId, session: u64, hidden: &Tensor) -> Result<Tensor>;
    /// One decode step over the server's span.
    fn step(
        &self,
        server: NodeId,
        session: u64,
        cache_len: usize,
        hidden: &Tensor,
    ) -> Result<Tensor>;
    /// One RAGGED decode step: `row_lens[r]` is row r's own cache
    /// length, so a multi-prompt session advances rows at different
    /// depths in one call (wire v5). The default forwards uniform
    /// batches to [`Self::step`] — transports and test fakes that
    /// predate ragged batching keep working for lockstep traffic — and
    /// rejects genuinely mixed depths.
    fn step_ragged(
        &self,
        server: NodeId,
        session: u64,
        row_lens: &[usize],
        hidden: &Tensor,
    ) -> Result<Tensor> {
        match row_lens.first() {
            Some(&l) if row_lens.iter().all(|&x| x == l) => {
                self.step(server, session, l, hidden)
            }
            Some(_) => Err(Error::Protocol(
                "transport does not support ragged (mixed-depth) steps".into(),
            )),
            None => Err(Error::Shape("empty row_lens".into())),
        }
    }
    /// One decode step carrying a wire-v7 trace context: the server
    /// returns its per-stage timing breakdown (queue wait, fuse wait, KV
    /// gather, executor, commit) alongside the hidden states. The
    /// default forwards to [`Self::step_ragged`] and reports no
    /// breakdown — transports and test fakes that predate tracing keep
    /// working; the client just renders a hop with RTT only.
    fn step_traced(
        &self,
        server: NodeId,
        session: u64,
        row_lens: &[usize],
        hidden: &Tensor,
        _ctx: &TraceContext,
    ) -> Result<(Tensor, Option<StepBreakdown>)> {
        self.step_ragged(server, session, row_lens, hidden).map(|t| (t, None))
    }
    /// One speculative VERIFY round (wire v8 `ProposeVerify`): `hidden`
    /// is `[B, m, H]` — for each row, position `j` extends the cache at
    /// depth `base_lens[row] + j`. Returns the span outputs for all
    /// `B × m` positions in the same layout. Servers first roll the
    /// session's KV back to `base_lens` (discarding any speculative
    /// suffix a previous round left behind), then score the m positions
    /// sequentially so position `j` attends to the K/V written by
    /// positions `< j`. The default decomposes the round into m
    /// sequential [`Self::step_ragged`] calls — bitwise identical by
    /// construction, just one round-trip per position — so transports
    /// and test fakes that predate wire v8 keep working.
    fn propose_verify(
        &self,
        server: NodeId,
        session: u64,
        base_lens: &[usize],
        hidden: &Tensor,
    ) -> Result<Tensor> {
        verify_round_via_steps(self, server, session, base_lens, hidden)
    }
    fn close_session(&self, server: NodeId, session: u64);
    /// Release one finished row of a multi-row session (wire v6
    /// `CloseSessionRow`): its KV pages free immediately while the batch
    /// keeps its shape. Best-effort — the default no-op keeps transports
    /// and fakes that predate per-row exit working (a legacy server
    /// treats the unknown tag as a connection error, which callers
    /// swallow the same way).
    fn close_row(&self, _server: NodeId, _session: u64, _row: usize) -> Result<()> {
        Ok(())
    }
    /// Resolve a wire-v6 `moved:` redirect address to a dialable server
    /// id. The default (`None`) sends clients down the replay-based
    /// recovery path instead of the cheap redirect.
    fn resolve_moved(&self, _addr: &str) -> Option<NodeId> {
        None
    }
    /// Report one *measured* hop: `wall_s` seconds from sending a step
    /// to receiving its reply from `server`. Sessions call this on every
    /// successful decode step; transports that keep a measurement
    /// registry ([`crate::coordinator::throughput::MeasuredHops`])
    /// override it to feed `ServerView::measured_step_s`, so the next
    /// `find_chain` scores chains by what this client actually observed.
    /// Default: no-op (fakes and transports without a registry).
    fn observe_step(&self, _server: NodeId, _wall_s: f64) {}
    /// Stateless parallel forward over the span (fine-tuning, §2.2).
    fn forward(&self, server: NodeId, hidden: &Tensor) -> Result<Tensor>;
    /// Backward over the span; returns grad wrt the span's input.
    fn backward(&self, server: NodeId, hidden: &Tensor, grad: &Tensor) -> Result<Tensor>;
}

/// The pre-v8 decomposition of a speculative verify round: m sequential
/// [`ChainClient::step_ragged`] calls over the `[B, m, H]` payload's
/// position slices, at depths `base_lens + j`. Bitwise identical to the
/// fused wire-v8 frame by construction (the server executes the fused
/// frame as exactly these sub-steps) — only the round-trip count
/// differs. This is both the trait's default `propose_verify` and the
/// TCP transport's memoized downgrade for legacy peers.
pub fn verify_round_via_steps<C: ChainClient + ?Sized>(
    client: &C,
    server: NodeId,
    session: u64,
    base_lens: &[usize],
    hidden: &Tensor,
) -> Result<Tensor> {
    if hidden.shape.len() != 3 {
        return Err(Error::Shape(format!(
            "propose_verify wants [B, m, H], got {:?}",
            hidden.shape
        )));
    }
    let (b, m, h) = (hidden.shape[0], hidden.shape[1], hidden.shape[2]);
    if b == 0 || m == 0 || base_lens.len() != b {
        return Err(Error::Shape(format!(
            "propose_verify: {b} rows x {m} positions vs {} base lens",
            base_lens.len()
        )));
    }
    let src = hidden.as_f32();
    let mut out = vec![0f32; b * m * h];
    for j in 0..m {
        let mut pos = vec![0f32; b * h];
        for r in 0..b {
            pos[r * h..(r + 1) * h]
                .copy_from_slice(&src[(r * m + j) * h..(r * m + j + 1) * h]);
        }
        let lens: Vec<usize> = base_lens.iter().map(|&l| l + j).collect();
        let step =
            client.step_ragged(server, session, &lens, &Tensor::from_f32(&[b, 1, h], &pos))?;
        let sf = step.as_f32();
        for r in 0..b {
            out[(r * m + j) * h..(r * m + j + 1) * h].copy_from_slice(&sf[r * h..(r + 1) * h]);
        }
    }
    Ok(Tensor::from_f32(&[b, m, h], &out))
}

/// Forwarding impls so sessions can either borrow a swarm (`&C`, the
/// generator / test path) or co-own it (`Arc<C>`, the HTTP API's
/// persistent-session store, which must hold sessions across requests).
impl<T: ChainClient + ?Sized> ChainClient for &T {
    fn discover(&self) -> Vec<ServerView> {
        (**self).discover()
    }
    fn open_session(
        &self,
        server: NodeId,
        session: u64,
        batch: usize,
        prefix_len: usize,
        max_new: usize,
    ) -> Result<()> {
        (**self).open_session(server, session, batch, prefix_len, max_new)
    }
    #[allow(clippy::too_many_arguments)]
    fn open_session_prefixed(
        &self,
        server: NodeId,
        session: u64,
        batch: usize,
        prefix_len: usize,
        max_new: usize,
        prefix_tokens: &[i32],
        prefill_width: usize,
    ) -> Result<()> {
        (**self).open_session_prefixed(
            server,
            session,
            batch,
            prefix_len,
            max_new,
            prefix_tokens,
            prefill_width,
        )
    }
    fn prefill(&self, server: NodeId, session: u64, hidden: &Tensor) -> Result<Tensor> {
        (**self).prefill(server, session, hidden)
    }
    fn step(
        &self,
        server: NodeId,
        session: u64,
        cache_len: usize,
        hidden: &Tensor,
    ) -> Result<Tensor> {
        (**self).step(server, session, cache_len, hidden)
    }
    fn step_ragged(
        &self,
        server: NodeId,
        session: u64,
        row_lens: &[usize],
        hidden: &Tensor,
    ) -> Result<Tensor> {
        (**self).step_ragged(server, session, row_lens, hidden)
    }
    fn step_traced(
        &self,
        server: NodeId,
        session: u64,
        row_lens: &[usize],
        hidden: &Tensor,
        ctx: &TraceContext,
    ) -> Result<(Tensor, Option<StepBreakdown>)> {
        (**self).step_traced(server, session, row_lens, hidden, ctx)
    }
    fn propose_verify(
        &self,
        server: NodeId,
        session: u64,
        base_lens: &[usize],
        hidden: &Tensor,
    ) -> Result<Tensor> {
        (**self).propose_verify(server, session, base_lens, hidden)
    }
    fn close_session(&self, server: NodeId, session: u64) {
        (**self).close_session(server, session)
    }
    fn close_row(&self, server: NodeId, session: u64, row: usize) -> Result<()> {
        (**self).close_row(server, session, row)
    }
    fn resolve_moved(&self, addr: &str) -> Option<NodeId> {
        (**self).resolve_moved(addr)
    }
    fn observe_step(&self, server: NodeId, wall_s: f64) {
        (**self).observe_step(server, wall_s)
    }
    fn forward(&self, server: NodeId, hidden: &Tensor) -> Result<Tensor> {
        (**self).forward(server, hidden)
    }
    fn backward(&self, server: NodeId, hidden: &Tensor, grad: &Tensor) -> Result<Tensor> {
        (**self).backward(server, hidden, grad)
    }
}

impl<T: ChainClient + ?Sized> ChainClient for std::sync::Arc<T> {
    fn discover(&self) -> Vec<ServerView> {
        (**self).discover()
    }
    fn open_session(
        &self,
        server: NodeId,
        session: u64,
        batch: usize,
        prefix_len: usize,
        max_new: usize,
    ) -> Result<()> {
        (**self).open_session(server, session, batch, prefix_len, max_new)
    }
    #[allow(clippy::too_many_arguments)]
    fn open_session_prefixed(
        &self,
        server: NodeId,
        session: u64,
        batch: usize,
        prefix_len: usize,
        max_new: usize,
        prefix_tokens: &[i32],
        prefill_width: usize,
    ) -> Result<()> {
        (**self).open_session_prefixed(
            server,
            session,
            batch,
            prefix_len,
            max_new,
            prefix_tokens,
            prefill_width,
        )
    }
    fn prefill(&self, server: NodeId, session: u64, hidden: &Tensor) -> Result<Tensor> {
        (**self).prefill(server, session, hidden)
    }
    fn step(
        &self,
        server: NodeId,
        session: u64,
        cache_len: usize,
        hidden: &Tensor,
    ) -> Result<Tensor> {
        (**self).step(server, session, cache_len, hidden)
    }
    fn step_ragged(
        &self,
        server: NodeId,
        session: u64,
        row_lens: &[usize],
        hidden: &Tensor,
    ) -> Result<Tensor> {
        (**self).step_ragged(server, session, row_lens, hidden)
    }
    fn step_traced(
        &self,
        server: NodeId,
        session: u64,
        row_lens: &[usize],
        hidden: &Tensor,
        ctx: &TraceContext,
    ) -> Result<(Tensor, Option<StepBreakdown>)> {
        (**self).step_traced(server, session, row_lens, hidden, ctx)
    }
    fn propose_verify(
        &self,
        server: NodeId,
        session: u64,
        base_lens: &[usize],
        hidden: &Tensor,
    ) -> Result<Tensor> {
        (**self).propose_verify(server, session, base_lens, hidden)
    }
    fn close_session(&self, server: NodeId, session: u64) {
        (**self).close_session(server, session)
    }
    fn close_row(&self, server: NodeId, session: u64, row: usize) -> Result<()> {
        (**self).close_row(server, session, row)
    }
    fn resolve_moved(&self, addr: &str) -> Option<NodeId> {
        (**self).resolve_moved(addr)
    }
    fn observe_step(&self, server: NodeId, wall_s: f64) {
        (**self).observe_step(server, wall_s)
    }
    fn forward(&self, server: NodeId, hidden: &Tensor) -> Result<Tensor> {
        (**self).forward(server, hidden)
    }
    fn backward(&self, server: NodeId, hidden: &Tensor, grad: &Tensor) -> Result<Tensor> {
        (**self).backward(server, hidden, grad)
    }
}

/// Per-request prompt geometry — *derived from the prompt itself*, never
/// caller-configured. The streaming API redesign removed the fixed
/// `prefix_len`/`prefill_width` coupling from [`SessionConfig`]: clients
/// now pick the smallest compiled prefill width that fits the prompt
/// ([`crate::coordinator::client::LocalHead::derive_prefill_width`]) and
/// reject over-long prompts with [`Error::PromptTooLong`] instead of
/// padding/truncating.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PromptShape {
    pub batch: usize,
    /// Valid prompt length (<= prefill_width).
    pub prefix_len: usize,
    /// Padded width the prefill artifact expects. Padding sits *after*
    /// the valid positions, so causal masking keeps it invisible.
    pub prefill_width: usize,
}

impl PromptShape {
    pub fn validate(&self) -> Result<()> {
        if self.batch == 0 || self.prefix_len == 0 {
            return Err(Error::Shape("empty prompt".into()));
        }
        if self.prefix_len > self.prefill_width {
            return Err(Error::Shape(format!(
                "prefix_len {} exceeds prefill width {}",
                self.prefix_len, self.prefill_width
            )));
        }
        Ok(())
    }
}

/// Session parameters that are genuinely *session policy* (routing,
/// retry budget, decode-token reservation). Prompt geometry moved to
/// [`PromptShape`].
#[derive(Debug, Clone)]
pub struct SessionConfig {
    pub n_blocks: usize,
    /// Decode-token budget reserved on servers at open (admission
    /// control); generation requests may ask for fewer.
    pub max_new: usize,
    pub route: RouteQuery,
    /// Retries across re-routing before giving up.
    pub max_recoveries: usize,
    /// The session's prefix token ids (batch-1 sessions; empty disables
    /// prefix identity). MUST equal the session's *entire* prompt — a
    /// truncated "template" here would exact-match another session's
    /// registration and be served its cached prefill output
    /// ([`crate::coordinator::client::SwarmGenerator`] enforces this).
    /// Sent with wire-v3 opens so servers can share cached prefix KV;
    /// also the source of `route.prefix_fp` (fingerprinted over the
    /// page-aligned leading span) for cache-aware sticky routing.
    pub prefix_tokens: Vec<i32>,
}

/// Per-hop replay history: what the client sent to this server.
#[derive(Clone, Default)]
struct HopHistory {
    prefill_input: Option<Tensor>,
    step_inputs: Vec<(Vec<usize>, Tensor)>, // (per-row cache lens, hidden)
}

/// One hop's portion of a [`SessionState`] snapshot: the block span it
/// covered and the exact inputs the client sent it (the §3.2 replay
/// history, which is also everything a *different* chain needs to
/// rebuild identical KV for those blocks).
#[derive(Clone)]
pub struct HopState {
    pub start: usize,
    pub end: usize,
    pub prefill_input: Option<Tensor>,
    /// `(per-row cache lens, hidden)` per decode step, in order.
    pub step_inputs: Vec<(Vec<usize>, Tensor)>,
}

/// A client-side snapshot of a live session — everything needed to
/// rebuild it on a fresh chain ([`InferenceSession::restore`]) with
/// bitwise-identical KV state: prompt geometry, per-row cache lengths,
/// and each hop's replay history. The durability complement to the
/// server-side KV snapshot (`server::SessionSnapshot`): that one moves
/// caches between servers, this one survives losing the whole chain.
#[derive(Clone)]
pub struct SessionState {
    pub session_id: u64,
    pub shape: PromptShape,
    pub row_lens: Vec<usize>,
    pub hops: Vec<HopState>,
}

/// How many NotFound replies a client tolerates right after following a
/// `moved:` redirect (at 10ms apart): the redirect can reach the target
/// before the donor's migration push finishes restoring the session
/// there. After the grace window, the client falls back to replay.
const MOVED_GRACE_TRIES: usize = 50;

/// A live pipeline-parallel inference session. Owns its `ChainClient`
/// handle (`&C` and `Arc<C>` both implement [`ChainClient`] by
/// forwarding), so a session can either borrow the swarm for one
/// request or co-own it across HTTP requests (the persistent-session
/// endpoints).
pub struct InferenceSession<C: ChainClient> {
    client: C,
    cfg: SessionConfig,
    shape: PromptShape,
    chain: Vec<ChainHop>,
    history: Vec<HopHistory>,
    session_id: u64,
    /// Per-row cache lengths (`row_lens.len() == shape.batch`). Uniform
    /// sessions keep every slot equal; a ragged multi-prompt session's
    /// rows advance from their own prompt lengths.
    row_lens: Vec<usize>,
    /// Per-hop `[B, m, H]` inputs of an in-flight speculative verify
    /// round ([`Self::propose_verify`]), held until the caller decides
    /// how many positions survived ([`Self::commit_verify`]). Only the
    /// committed slices enter `history` — replay history stays a truthful
    /// per-token record that legacy replacement servers can replay.
    pending_verify: Vec<Tensor>,
    recoveries: usize,
}

impl<C: ChainClient> InferenceSession<C> {
    /// Discover servers, pick a chain, open per-server sessions. If any
    /// hop rejects the open (e.g. [`Error::Busy`] admission control),
    /// the hops already opened are closed before the error propagates —
    /// otherwise their KV-page reservations would leak until the
    /// server's idle-session sweep reclaimed them.
    pub fn open(client: C, cfg: SessionConfig, shape: PromptShape, session_id: u64) -> Result<Self> {
        let lens = vec![shape.prefix_len; shape.batch];
        Self::open_ragged(client, cfg, shape, lens, session_id)
    }

    /// [`Self::open`] with per-row prompt lengths — the multi-prompt
    /// ragged path: one session whose rows start (and keep advancing) at
    /// different cache depths. `shape.prefix_len` must equal the deepest
    /// row (`row_lens.iter().max()`), and every row must be non-empty.
    pub fn open_ragged(
        client: C,
        cfg: SessionConfig,
        shape: PromptShape,
        row_lens: Vec<usize>,
        session_id: u64,
    ) -> Result<Self> {
        shape.validate()?;
        if row_lens.len() != shape.batch {
            return Err(Error::Shape(format!(
                "{} row lens for batch {}",
                row_lens.len(),
                shape.batch
            )));
        }
        if row_lens.iter().any(|&l| l == 0 || l > shape.prefix_len)
            || row_lens.iter().max() != Some(&shape.prefix_len)
        {
            return Err(Error::Shape(format!(
                "row lens {row_lens:?} inconsistent with prefix_len {}",
                shape.prefix_len
            )));
        }
        let servers = client.discover();
        let (chain, _cost) = routing::find_chain(&servers, &cfg.route)
            .ok_or_else(|| Error::NoRoute("no chain covers all blocks".into()))?;
        for (i, hop) in chain.iter().enumerate() {
            if let Err(e) = client.open_session_prefixed(
                hop.server,
                session_id,
                shape.batch,
                shape.prefix_len,
                cfg.max_new,
                &cfg.prefix_tokens,
                shape.prefill_width,
            ) {
                for opened in &chain[..i] {
                    client.close_session(opened.server, session_id);
                }
                return Err(e);
            }
        }
        let history = vec![HopHistory::default(); chain.len()];
        Ok(InferenceSession {
            client,
            cfg,
            shape,
            chain,
            history,
            session_id,
            row_lens,
            pending_verify: Vec::new(),
            recoveries: 0,
        })
    }

    pub fn chain(&self) -> &[ChainHop] {
        &self.chain
    }

    /// The deepest row's cache length (the uniform length for lockstep
    /// sessions).
    pub fn cache_len(&self) -> usize {
        self.row_lens.iter().copied().max().unwrap_or(0)
    }

    /// Per-row cache lengths (ragged sessions' rows differ).
    pub fn row_lens(&self) -> &[usize] {
        &self.row_lens
    }

    pub fn shape(&self) -> PromptShape {
        self.shape
    }

    pub fn recoveries(&self) -> usize {
        self.recoveries
    }

    /// Run the padded prefix through the whole chain. Returns the final
    /// hidden states [B, prefill_width, H].
    pub fn prefill(&mut self, hidden: Tensor) -> Result<Tensor> {
        let mut h = hidden;
        let mut i = 0;
        let mut moved_grace = 0usize;
        while i < self.chain.len() {
            self.history[i].prefill_input = Some(h.clone());
            match self.client.prefill(self.chain[i].server, self.session_id, &h) {
                Ok(next) => {
                    h = next;
                    i += 1;
                    moved_grace = 0;
                }
                Err(Error::Moved(addr)) => {
                    // live migration: follow the redirect (no replay —
                    // the new server holds the KV already); fall back to
                    // replay recovery when the address is unknown
                    if self.redirect(i, &addr) {
                        moved_grace = MOVED_GRACE_TRIES;
                    } else {
                        self.recover(i)?;
                    }
                }
                Err(Error::NotFound(_)) if moved_grace > 0 => {
                    // redirect raced the migration push: the new server
                    // has not restored the session yet — wait briefly
                    moved_grace -= 1;
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(e) if e.is_retryable() => {
                    self.recover(i)?;
                    // retry same index against the replacement
                }
                Err(e) => return Err(e),
            }
        }
        Ok(h)
    }

    /// One decode step through the whole chain: hidden [B,1,H] in/out.
    /// Per-row cache lengths are managed internally (each row starts at
    /// its own prompt length and advances by one per step); uniform
    /// sessions travel as classic `InferStep` frames, ragged ones as
    /// wire-v5 `InferStepRagged`.
    pub fn step(&mut self, hidden: Tensor) -> Result<Tensor> {
        self.step_impl(hidden, None).map(|(h, _)| h)
    }

    /// [`Self::step`] carrying a wire-v7 trace context: returns the
    /// hidden states plus one [`HopTrace`] per chain hop (client-side
    /// RTT always; the server-side stage breakdown whenever the hop
    /// speaks v7). Recovery and `moved:` redirects behave exactly as in
    /// the untraced step — a hop that failed and was replaced is traced
    /// under its replacement.
    pub fn step_traced(
        &mut self,
        hidden: Tensor,
        ctx: &TraceContext,
    ) -> Result<(Tensor, Vec<HopTrace>)> {
        self.step_impl(hidden, Some(ctx))
    }

    fn step_impl(
        &mut self,
        hidden: Tensor,
        ctx: Option<&TraceContext>,
    ) -> Result<(Tensor, Vec<HopTrace>)> {
        let mut h = hidden;
        let mut i = 0;
        let mut moved_grace = 0usize;
        let mut hops: Vec<HopTrace> = Vec::new();
        while i < self.chain.len() {
            self.history[i].step_inputs.push((self.row_lens.clone(), h.clone()));
            // every hop is clocked (not just traced ones): successful
            // steps feed the transport's measurement registry so routing
            // learns this client's real per-hop throughput
            let clock = std::time::Instant::now();
            let t0 = ctx.map(|_| clock);
            let outcome = match ctx {
                Some(c) => self.client.step_traced(
                    self.chain[i].server,
                    self.session_id,
                    &self.row_lens,
                    &h,
                    c,
                ),
                None => self
                    .client
                    .step_ragged(self.chain[i].server, self.session_id, &self.row_lens, &h)
                    .map(|t| (t, None)),
            };
            match outcome {
                Ok((next, breakdown)) => {
                    self.client
                        .observe_step(self.chain[i].server, clock.elapsed().as_secs_f64());
                    if let Some(t0) = t0 {
                        hops.push(HopTrace {
                            server: self.chain[i].server.short(),
                            start: self.chain[i].start,
                            end: self.chain[i].end,
                            rtt_us: t0.elapsed().as_micros().min(u32::MAX as u128) as u32,
                            breakdown,
                        });
                    }
                    h = next;
                    i += 1;
                    moved_grace = 0;
                }
                Err(Error::Moved(addr)) => {
                    // live migration: the new server already holds this
                    // session's KV — swap the hop and retry WITHOUT
                    // replaying (replay would double-write the caches)
                    self.history[i].step_inputs.pop();
                    if self.redirect(i, &addr) {
                        moved_grace = MOVED_GRACE_TRIES;
                    } else {
                        self.recover(i)?;
                    }
                }
                Err(Error::NotFound(_)) if moved_grace > 0 => {
                    // the redirect outran the migration push; the session
                    // appears on the new server within milliseconds
                    self.history[i].step_inputs.pop();
                    moved_grace -= 1;
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(e) if e.is_retryable() => {
                    // drop the just-recorded input; recovery replays it
                    self.history[i].step_inputs.pop();
                    self.recover(i)?;
                }
                Err(e) => return Err(e),
            }
        }
        for l in &mut self.row_lens {
            *l += 1;
        }
        Ok((h, hops))
    }

    /// One speculative VERIFY round through the whole chain (wire v8):
    /// `hidden` is `[B, m, H]` — position `j` of each row extends that
    /// row's cache at depth `row_lens[row] + j`. Returns the chain's
    /// outputs for all positions in the same layout. This does NOT
    /// advance `row_lens` or record replay history: the caller inspects
    /// the outputs, decides how many leading positions survive
    /// verification, and calls [`Self::commit_verify`] — only the
    /// committed per-token slices enter the replay history, so recovery
    /// and restore work against legacy (pre-v8) replacement servers
    /// unchanged. Rejected suffix KV on the servers needs no explicit
    /// cleanup: the next frame's smaller declared lengths trigger the
    /// server-side implicit rollback.
    ///
    /// Failure handling mirrors [`Self::step`]: `moved:` redirects are
    /// followed, retryable hop failures recover by replaying the
    /// (committed-only) history onto a replacement and re-sending this
    /// round — bitwise-safe because the round is idempotent from the
    /// committed base.
    pub fn propose_verify(&mut self, hidden: Tensor) -> Result<Tensor> {
        if hidden.shape.len() != 3 || hidden.shape[0] != self.shape.batch {
            return Err(Error::Shape(format!(
                "propose_verify wants [{}, m, H], got {:?}",
                self.shape.batch, hidden.shape
            )));
        }
        self.pending_verify.clear();
        let mut h = hidden;
        let mut i = 0;
        let mut moved_grace = 0usize;
        while i < self.chain.len() {
            self.pending_verify.push(h.clone());
            match self.client.propose_verify(
                self.chain[i].server,
                self.session_id,
                &self.row_lens,
                &h,
            ) {
                Ok(next) => {
                    h = next;
                    i += 1;
                    moved_grace = 0;
                }
                Err(Error::Moved(addr)) => {
                    self.pending_verify.pop();
                    if self.redirect(i, &addr) {
                        moved_grace = MOVED_GRACE_TRIES;
                    } else {
                        self.recover(i)?;
                    }
                }
                Err(Error::NotFound(_)) if moved_grace > 0 => {
                    self.pending_verify.pop();
                    moved_grace -= 1;
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(e) if e.is_retryable() => {
                    self.pending_verify.pop();
                    self.recover(i)?;
                }
                Err(e) => return Err(e),
            }
        }
        Ok(h)
    }

    /// Commit the first `committed` positions of the verify round sent
    /// by the last [`Self::propose_verify`]: each hop's `[B, m, H]`
    /// input is sliced into `committed` per-token `[B, 1, H]` replay
    /// entries (exactly the frames a non-speculative client would have
    /// sent) and `row_lens` advances by `committed`. Positions past
    /// `committed` vanish from client state; the servers shed them on
    /// the next frame via implicit rollback.
    pub fn commit_verify(&mut self, committed: usize) -> Result<()> {
        if self.pending_verify.len() != self.chain.len() {
            return Err(Error::Protocol(
                "commit_verify without a completed propose_verify round".into(),
            ));
        }
        let m = self.pending_verify.first().map(|t| t.shape[1]).unwrap_or(0);
        if committed == 0 || committed > m {
            return Err(Error::Shape(format!(
                "commit_verify: {committed} of {m} positions"
            )));
        }
        let pending = std::mem::take(&mut self.pending_verify);
        for (hist, inp) in self.history.iter_mut().zip(&pending) {
            let (b, hm, hd) = (inp.shape[0], inp.shape[1], inp.shape[2]);
            let src = inp.as_f32();
            for j in 0..committed.min(hm) {
                let mut pos = vec![0f32; b * hd];
                for r in 0..b {
                    pos[r * hd..(r + 1) * hd]
                        .copy_from_slice(&src[(r * hm + j) * hd..(r * hm + j + 1) * hd]);
                }
                let lens: Vec<usize> = self.row_lens.iter().map(|&l| l + j).collect();
                hist.step_inputs.push((lens, Tensor::from_f32(&[b, 1, hd], &pos)));
            }
        }
        for l in &mut self.row_lens {
            *l += committed;
        }
        Ok(())
    }

    /// Follow a wire-v6 `moved:` redirect for hop `i`: resolve the new
    /// address and swap the hop in place, keeping its replay history (the
    /// migrated server holds the same KV the old one did). Returns false
    /// when the address cannot be resolved — or resolves to a server
    /// already serving another span of this chain, which would collide on
    /// the session id — in which case the caller replays instead.
    fn redirect(&mut self, i: usize, addr: &str) -> bool {
        match self.client.resolve_moved(addr) {
            Some(id)
                if !self
                    .chain
                    .iter()
                    .enumerate()
                    .any(|(j, h)| j != i && h.server == id) =>
            {
                self.chain[i].server = id;
                true
            }
            _ => false,
        }
    }

    /// Replace the failed hop `i` with a fresh subchain and replay its
    /// history so the replacements hold identical KV caches.
    fn recover(&mut self, i: usize) -> Result<()> {
        self.recoveries += 1;
        if self.recoveries > self.cfg.max_recoveries {
            return Err(Error::ChainBroken(format!(
                "exceeded {} recoveries",
                self.cfg.max_recoveries
            )));
        }
        let failed = self.chain[i].clone();
        // exclude EVERY server already in the chain, not just the failed
        // one: per-server session state is keyed by session id alone, so
        // re-opening this session on an in-chain server would clobber the
        // caches it holds for its other span
        let in_chain: Vec<NodeId> = self.chain.iter().map(|h| h.server).collect();
        let servers: Vec<ServerView> = self
            .client
            .discover()
            .into_iter()
            .filter(|s| !in_chain.contains(&s.id))
            .collect();
        let sub = routing::find_subchain(&servers, &self.cfg.route, failed.start, failed.end)
            .ok_or_else(|| {
                Error::NoRoute(format!(
                    "no replacement for blocks {}..{}",
                    failed.start, failed.end
                ))
            })?;
        // open sessions on the replacements + replay history (§3.2: "the
        // client sends all previous inputs to the replacement server");
        // on any failure, close what was opened so pool reservations on
        // the replacements don't leak
        let result = (|| -> Result<Vec<HopHistory>> {
            for hop in &sub {
                self.client.open_session_prefixed(
                    hop.server,
                    self.session_id,
                    self.shape.batch,
                    self.shape.prefix_len,
                    self.cfg.max_new,
                    &self.cfg.prefix_tokens,
                    self.shape.prefill_width,
                )?;
            }
            let old_history = self.history[i].clone();
            let mut sub_history = vec![HopHistory::default(); sub.len()];
            if let Some(pre) = &old_history.prefill_input {
                let mut h = pre.clone();
                for (j, hop) in sub.iter().enumerate() {
                    sub_history[j].prefill_input = Some(h.clone());
                    h = self.client.prefill(hop.server, self.session_id, &h)?;
                }
            }
            for (lens, inp) in &old_history.step_inputs {
                let mut h = inp.clone();
                for (j, hop) in sub.iter().enumerate() {
                    sub_history[j].step_inputs.push((lens.clone(), h.clone()));
                    h = self.client.step_ragged(hop.server, self.session_id, lens, &h)?;
                }
            }
            Ok(sub_history)
        })();
        let sub_history = match result {
            Ok(h) => h,
            Err(e) => {
                for hop in &sub {
                    self.client.close_session(hop.server, self.session_id);
                }
                return Err(e);
            }
        };
        // splice the replacement hop(s) in
        self.chain.splice(i..=i, sub);
        self.history.splice(i..=i, sub_history);
        Ok(())
    }

    /// Release one finished row's KV pages on every hop (per-row early
    /// exit). Best-effort: a hop that predates wire v6 drops the frame's
    /// connection, which the transport maps to an error we ignore — the
    /// row's pages then free at session close like before.
    pub fn close_row(&self, row: usize) {
        for hop in &self.chain {
            let _ = self.client.close_row(hop.server, self.session_id, row);
        }
    }

    /// Capture a client-side snapshot: prompt geometry, per-row cache
    /// lengths, and every hop's replay history. [`Self::restore`] rebuilds
    /// an equivalent session on a *fresh* chain from this alone.
    pub fn snapshot(&self) -> SessionState {
        SessionState {
            session_id: self.session_id,
            shape: self.shape,
            row_lens: self.row_lens.clone(),
            hops: self
                .chain
                .iter()
                .zip(&self.history)
                .map(|(hop, hist)| HopState {
                    start: hop.start,
                    end: hop.end,
                    prefill_input: hist.prefill_input.clone(),
                    step_inputs: hist.step_inputs.clone(),
                })
                .collect(),
        }
    }

    /// Rebuild a session from a [`SessionState`] snapshot on whatever
    /// servers are currently available, replaying each saved hop's
    /// history so the new chain holds bitwise-identical KV. The original
    /// chain is assumed gone (client restart, total chain loss); servers
    /// that DO still hold the session id are excluded per-span only by
    /// the usual no-duplicate rule, so prefer a fresh `session_id` in the
    /// snapshot when the old chain may be partially alive.
    pub fn restore(client: C, cfg: SessionConfig, state: SessionState) -> Result<Self> {
        state.shape.validate()?;
        if state.row_lens.len() != state.shape.batch {
            return Err(Error::Shape(format!(
                "{} row lens for batch {}",
                state.row_lens.len(),
                state.shape.batch
            )));
        }
        if state.hops.is_empty() {
            return Err(Error::Shape("snapshot has no hops".into()));
        }
        let servers = client.discover();
        let mut chain: Vec<ChainHop> = Vec::new();
        let mut history: Vec<HopHistory> = Vec::new();
        let result = (|| -> Result<()> {
            for hs in &state.hops {
                // per-server session state is keyed by session id alone,
                // so no server may serve two spans of the same session
                let used: Vec<NodeId> = chain.iter().map(|h| h.server).collect();
                let avail: Vec<ServerView> = servers
                    .iter()
                    .filter(|s| !used.contains(&s.id))
                    .cloned()
                    .collect();
                let sub = routing::find_subchain(&avail, &cfg.route, hs.start, hs.end)
                    .ok_or_else(|| {
                        Error::NoRoute(format!(
                            "no chain covers blocks {}..{} for restore",
                            hs.start, hs.end
                        ))
                    })?;
                let base = chain.len();
                for hop in &sub {
                    client.open_session_prefixed(
                        hop.server,
                        state.session_id,
                        state.shape.batch,
                        state.shape.prefix_len,
                        cfg.max_new,
                        &cfg.prefix_tokens,
                        state.shape.prefill_width,
                    )?;
                    // record immediately so the error path closes it
                    chain.push(hop.clone());
                    history.push(HopHistory::default());
                }
                // replay this hop's saved inputs through its sub-chain,
                // recording what each replacement hop actually saw
                if let Some(pre) = &hs.prefill_input {
                    let mut h = pre.clone();
                    for (j, hop) in sub.iter().enumerate() {
                        history[base + j].prefill_input = Some(h.clone());
                        h = client.prefill(hop.server, state.session_id, &h)?;
                    }
                }
                for (lens, inp) in &hs.step_inputs {
                    let mut h = inp.clone();
                    for (j, hop) in sub.iter().enumerate() {
                        history[base + j].step_inputs.push((lens.clone(), h.clone()));
                        h = client.step_ragged(hop.server, state.session_id, lens, &h)?;
                    }
                }
            }
            Ok(())
        })();
        if let Err(e) = result {
            for hop in &chain {
                client.close_session(hop.server, state.session_id);
            }
            return Err(e);
        }
        Ok(InferenceSession {
            client,
            cfg,
            shape: state.shape,
            chain,
            history,
            session_id: state.session_id,
            row_lens: state.row_lens,
            pending_verify: Vec::new(),
            recoveries: 0,
        })
    }

    /// Close all per-server sessions.
    pub fn close(self) {
        for hop in &self.chain {
            self.client.close_session(hop.server, self.session_id);
        }
    }
}

/// Stateless parallel forward through a chain (no sessions/caches):
/// routes, then pipes [B,S,H] through every span; retries via re-route.
pub fn chain_forward<C: ChainClient>(
    client: &C,
    route: &RouteQuery,
    hidden: Tensor,
) -> Result<Tensor> {
    let servers = client.discover();
    let (chain, _) = routing::find_chain(&servers, route)
        .ok_or_else(|| Error::NoRoute("no chain".into()))?;
    let mut h = hidden;
    for hop in &chain {
        h = client.forward(hop.server, &h)?;
    }
    Ok(h)
}

/// Stateless backward through a chain (§2.2): re-runs the forward to
/// collect each span's input activation, then chains `backward` in
/// reverse. Returns the gradient wrt `x0`. Callers that already hold
/// the span inputs from a matching forward can skip the recompute (see
/// `finetune::ChainActivations`).
pub fn chain_backward<C: ChainClient>(
    client: &C,
    route: &RouteQuery,
    x0: &Tensor,
    grad_out: &Tensor,
) -> Result<Tensor> {
    let servers = client.discover();
    let (chain, _) = routing::find_chain(&servers, route)
        .ok_or_else(|| Error::NoRoute("no chain".into()))?;
    let mut inputs = Vec::with_capacity(chain.len());
    let mut h = x0.clone();
    for hop in &chain {
        inputs.push(h.clone());
        h = client.forward(hop.server, &h)?;
    }
    let mut g = grad_out.clone();
    for (i, hop) in chain.iter().enumerate().rev() {
        g = client.backward(hop.server, &inputs[i], &g)?;
    }
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tensor::DType;
    use std::cell::RefCell;
    use std::collections::HashMap;

    /// A scripted fake swarm: "computes" by adding +1 per block, tracks
    /// sessions/caches, and can be told to kill servers mid-flight.
    struct FakeSwarm {
        state: RefCell<FakeState>,
    }

    #[derive(Default)]
    struct FakeState {
        servers: Vec<FakeServer>,
        open_calls: usize,
    }

    struct FakeServer {
        id: NodeId,
        start: usize,
        end: usize,
        alive: bool,
        // session -> (#prefills, #steps) — to verify replay
        sessions: HashMap<u64, (usize, Vec<usize>)>,
        // per-row cache-length vectors served via step_ragged
        ragged_served: Vec<Vec<usize>>,
        fail_next: usize,      // fail this many next prefill/step requests
        fail_open_next: usize, // reject this many next open_session calls (Busy)
        // live migration fake: requests bounce with Moved(addr)
        moved_to: Option<String>,
        // restore lag: serve this many NotFound replies for unknown
        // sessions before "the migration push lands" (auto-registers)
        restore_after: usize,
        rows_closed: Vec<(u64, usize)>,
    }

    impl FakeSwarm {
        fn new(spans: &[(&str, usize, usize)]) -> Self {
            let servers = spans
                .iter()
                .map(|(n, s, e)| FakeServer {
                    id: NodeId::from_name(n),
                    start: *s,
                    end: *e,
                    alive: true,
                    sessions: HashMap::new(),
                    ragged_served: Vec::new(),
                    fail_next: 0,
                    fail_open_next: 0,
                    moved_to: None,
                    restore_after: 0,
                    rows_closed: Vec::new(),
                })
                .collect();
            FakeSwarm { state: RefCell::new(FakeState { servers, open_calls: 0 }) }
        }

        fn kill(&self, name: &str) {
            let id = NodeId::from_name(name);
            let mut st = self.state.borrow_mut();
            st.servers.iter_mut().find(|s| s.id == id).unwrap().alive = false;
        }

        /// Fake a live migration of `session` from `victim` to `target`:
        /// the victim starts bouncing requests with `Moved(target)`, and
        /// the target "restores" the pushed KV after serving `lag`
        /// NotFound replies (modelling the redirect racing the push).
        fn migrate(&self, victim: &str, target: &str, lag: usize) {
            let vid = NodeId::from_name(victim);
            let tid = NodeId::from_name(target);
            let mut st = self.state.borrow_mut();
            st.servers.iter_mut().find(|s| s.id == vid).unwrap().moved_to =
                Some(target.to_string());
            st.servers.iter_mut().find(|s| s.id == tid).unwrap().restore_after = lag;
        }

        fn steps_served(&self, name: &str, session: u64) -> Vec<usize> {
            let id = NodeId::from_name(name);
            let st = self.state.borrow();
            st.servers
                .iter()
                .find(|s| s.id == id)
                .and_then(|s| s.sessions.get(&session))
                .map(|(_, steps)| steps.clone())
                .unwrap_or_default()
        }

        fn apply(h: &Tensor, n_blocks: usize) -> Tensor {
            let mut out = h.clone();
            for v in out.as_f32_mut() {
                *v += n_blocks as f32;
            }
            out
        }
    }

    impl ChainClient for FakeSwarm {
        fn discover(&self) -> Vec<ServerView> {
            self.state
                .borrow()
                .servers
                .iter()
                .filter(|s| s.alive)
                .map(|s| ServerView {
                    id: s.id,
                    start: s.start,
                    end: s.end,
                    latency_s: 0.001,
                    bandwidth_bps: 1e9,
                    span_compute_s: 0.01 * (s.end - s.start) as f64,
                    queue_depth: 0,
                    free_ratio: 1.0,
                    prefix_fps: vec![],
                    p50_step_us: 0,
                    measured_step_s: None,
                    measured_age_s: 0.0,
                })
                .collect()
        }

        fn open_session(&self, server: NodeId, session: u64, _b: usize, _p: usize, _m: usize) -> Result<()> {
            let mut st = self.state.borrow_mut();
            st.open_calls += 1;
            let srv = st.servers.iter_mut().find(|s| s.id == server).unwrap();
            if !srv.alive {
                return Err(Error::ChainBroken("dead".into()));
            }
            if srv.fail_open_next > 0 {
                srv.fail_open_next -= 1;
                return Err(Error::Busy("kv pool full (fake)".into()));
            }
            srv.sessions.insert(session, (0, vec![]));
            Ok(())
        }

        fn prefill(&self, server: NodeId, session: u64, hidden: &Tensor) -> Result<Tensor> {
            let mut st = self.state.borrow_mut();
            let srv = st.servers.iter_mut().find(|s| s.id == server).unwrap();
            if !srv.alive || srv.fail_next > 0 {
                srv.fail_next = srv.fail_next.saturating_sub(1);
                return Err(Error::ChainBroken("prefill failed".into()));
            }
            if let Some(addr) = &srv.moved_to {
                return Err(Error::Moved(addr.clone()));
            }
            let span = srv.end - srv.start;
            srv.sessions.get_mut(&session).unwrap().0 += 1;
            Ok(FakeSwarm::apply(hidden, span))
        }

        fn step(&self, server: NodeId, session: u64, cache_len: usize, hidden: &Tensor) -> Result<Tensor> {
            let mut st = self.state.borrow_mut();
            let srv = st.servers.iter_mut().find(|s| s.id == server).unwrap();
            if !srv.alive || srv.fail_next > 0 {
                srv.fail_next = srv.fail_next.saturating_sub(1);
                return Err(Error::ChainBroken("step failed".into()));
            }
            if let Some(addr) = &srv.moved_to {
                return Err(Error::Moved(addr.clone()));
            }
            if !srv.sessions.contains_key(&session) {
                if srv.restore_after > 0 {
                    // migration push hasn't landed yet
                    srv.restore_after -= 1;
                    return Err(Error::NotFound("no such session".into()));
                }
                // the push "lands": KV arrives migrated, not replayed
                srv.sessions.insert(session, (0, vec![]));
            }
            let span = srv.end - srv.start;
            srv.sessions.get_mut(&session).unwrap().1.push(cache_len);
            Ok(FakeSwarm::apply(hidden, span))
        }

        fn step_ragged(
            &self,
            server: NodeId,
            session: u64,
            row_lens: &[usize],
            hidden: &Tensor,
        ) -> Result<Tensor> {
            // uniform batches ride the legacy path (like a real transport
            // downgrading to InferStep); mixed depths are recorded so the
            // replay tests can assert the exact per-row lens replayed
            if row_lens.windows(2).all(|w| w[0] == w[1]) {
                return self.step(server, session, row_lens[0], hidden);
            }
            let mut st = self.state.borrow_mut();
            let srv = st.servers.iter_mut().find(|s| s.id == server).unwrap();
            if !srv.alive || srv.fail_next > 0 {
                srv.fail_next = srv.fail_next.saturating_sub(1);
                return Err(Error::ChainBroken("ragged step failed".into()));
            }
            let span = srv.end - srv.start;
            srv.ragged_served.push(row_lens.to_vec());
            Ok(FakeSwarm::apply(hidden, span))
        }

        fn close_session(&self, server: NodeId, session: u64) {
            let mut st = self.state.borrow_mut();
            if let Some(srv) = st.servers.iter_mut().find(|s| s.id == server) {
                srv.sessions.remove(&session);
            }
        }

        fn close_row(&self, server: NodeId, session: u64, row: usize) -> Result<()> {
            let mut st = self.state.borrow_mut();
            let srv = st.servers.iter_mut().find(|s| s.id == server).unwrap();
            srv.rows_closed.push((session, row));
            Ok(())
        }

        fn resolve_moved(&self, addr: &str) -> Option<NodeId> {
            let id = NodeId::from_name(addr);
            let st = self.state.borrow();
            st.servers.iter().find(|s| s.id == id && s.alive).map(|s| s.id)
        }

        fn forward(&self, server: NodeId, hidden: &Tensor) -> Result<Tensor> {
            let st = self.state.borrow();
            let srv = st.servers.iter().find(|s| s.id == server).unwrap();
            if !srv.alive {
                return Err(Error::ChainBroken("dead".into()));
            }
            Ok(FakeSwarm::apply(hidden, srv.end - srv.start))
        }

        fn backward(&self, _server: NodeId, _hidden: &Tensor, grad: &Tensor) -> Result<Tensor> {
            Ok(grad.clone())
        }
    }

    fn cfg(n_blocks: usize) -> SessionConfig {
        SessionConfig {
            n_blocks,
            max_new: 8,
            route: RouteQuery { n_blocks, msg_bytes: 64, ..Default::default() },
            max_recoveries: 4,
            prefix_tokens: vec![],
        }
    }

    fn shape() -> PromptShape {
        PromptShape { batch: 1, prefix_len: 2, prefill_width: 4 }
    }

    fn h1() -> Tensor {
        Tensor::from_f32(&[1, 1, 4], &[0.0; 4])
    }

    #[test]
    fn full_pipeline_sums_all_blocks() {
        let swarm = FakeSwarm::new(&[("a", 0, 3), ("b", 3, 8)]);
        let mut s = InferenceSession::open(&swarm, cfg(8), shape(), 1).unwrap();
        let pre = Tensor::from_f32(&[1, 4, 4], &[0.0; 16]);
        let out = s.prefill(pre).unwrap();
        // +3 from a, +5 from b = 8 added to every element
        assert!(out.as_f32().iter().all(|&v| v == 8.0));
        let out = s.step(h1()).unwrap();
        assert!(out.as_f32().iter().all(|&v| v == 8.0));
        assert_eq!(s.cache_len(), 3);
        s.close();
    }

    #[test]
    fn step_failure_recovers_and_replays() {
        let swarm = FakeSwarm::new(&[("a", 0, 3), ("b", 3, 8), ("b2", 3, 8)]);
        let mut s = InferenceSession::open(&swarm, cfg(8), shape(), 7).unwrap();
        let pre = Tensor::from_f32(&[1, 4, 4], &[0.0; 16]);
        s.prefill(pre).unwrap();
        s.step(h1()).unwrap();
        s.step(h1()).unwrap();
        // the chain picked b or b2; kill whichever is in the chain
        let in_chain = s.chain()[1].server;
        let (victim, replacement) = if in_chain == NodeId::from_name("b") {
            ("b", "b2")
        } else {
            ("b2", "b")
        };
        swarm.kill(victim);
        let out = s.step(h1()).unwrap();
        assert!(out.as_f32().iter().all(|&v| v == 8.0), "math unchanged");
        assert_eq!(s.recoveries(), 1);
        assert_eq!(s.chain()[1].server, NodeId::from_name(replacement));
        // replacement must have replayed 2 old steps + served the new one:
        // cache_lens 2,3 (replay) then 4 (current)
        assert_eq!(swarm.steps_served(replacement, 7), vec![2, 3, 4]);
        assert_eq!(s.cache_len(), 5);
    }

    /// A ragged session's rows advance from their own prompt lengths,
    /// ragged steps travel through `step_ragged`, and recovery replays
    /// the exact per-row length vectors so a replacement server rebuilds
    /// identical per-row caches.
    #[test]
    fn ragged_session_steps_and_recovers_with_row_lens() {
        let swarm = FakeSwarm::new(&[("a", 0, 3), ("b", 3, 8), ("b2", 3, 8)]);
        let shape = PromptShape { batch: 2, prefix_len: 4, prefill_width: 4 };
        let mut s =
            InferenceSession::open_ragged(&swarm, cfg(8), shape, vec![2, 4], 11).unwrap();
        assert_eq!(s.row_lens(), &[2, 4]);
        s.prefill(Tensor::from_f32(&[2, 4, 4], &[0.0; 32])).unwrap();
        let h = Tensor::from_f32(&[2, 1, 4], &[0.0; 8]);
        s.step(h.clone()).unwrap();
        s.step(h.clone()).unwrap();
        assert_eq!(s.row_lens(), &[4, 6], "each row advanced independently");
        assert_eq!(s.cache_len(), 6);
        // every hop saw the ragged length vectors in order
        let served = |name: &str| {
            let st = swarm.state.borrow();
            st.servers
                .iter()
                .find(|x| x.id == NodeId::from_name(name))
                .unwrap()
                .ragged_served
                .clone()
        };
        let hop1 = s.chain()[1].server;
        let (victim, replacement) =
            if hop1 == NodeId::from_name("b") { ("b", "b2") } else { ("b2", "b") };
        assert_eq!(served(victim), vec![vec![2, 4], vec![3, 5]]);
        // kill the second hop mid-generation: the replacement must replay
        // BOTH historical ragged steps, then serve the new one
        swarm.kill(victim);
        let out = s.step(h).unwrap();
        assert!(out.as_f32().iter().all(|&v| v == 8.0), "math unchanged");
        assert_eq!(s.recoveries(), 1);
        assert_eq!(
            served(replacement),
            vec![vec![2, 4], vec![3, 5], vec![4, 6]],
            "replacement replayed the per-row history"
        );
        assert_eq!(s.row_lens(), &[5, 7]);
    }

    /// Defaulted transports (no step_ragged override) serve uniform
    /// sessions and reject mixed depths with a typed error.
    #[test]
    fn default_step_ragged_forwards_uniform_rejects_mixed() {
        struct Uniform;
        impl ChainClient for Uniform {
            fn discover(&self) -> Vec<ServerView> {
                vec![]
            }
            fn open_session(&self, _: NodeId, _: u64, _: usize, _: usize, _: usize) -> Result<()> {
                Ok(())
            }
            fn prefill(&self, _: NodeId, _: u64, h: &Tensor) -> Result<Tensor> {
                Ok(h.clone())
            }
            fn step(&self, _: NodeId, _: u64, cache_len: usize, h: &Tensor) -> Result<Tensor> {
                // tag the output with the scalar len the default passed
                let mut t = h.clone();
                t.as_f32_mut()[0] = cache_len as f32;
                Ok(t)
            }
            fn close_session(&self, _: NodeId, _: u64) {}
            fn forward(&self, _: NodeId, h: &Tensor) -> Result<Tensor> {
                Ok(h.clone())
            }
            fn backward(&self, _: NodeId, _: &Tensor, g: &Tensor) -> Result<Tensor> {
                Ok(g.clone())
            }
        }
        let u = Uniform;
        let id = NodeId::from_name("x");
        let h = Tensor::from_f32(&[2, 1, 2], &[0.0; 4]);
        let out = u.step_ragged(id, 1, &[7, 7], &h).unwrap();
        assert_eq!(out.as_f32()[0], 7.0, "uniform rows forwarded to step()");
        let err = u.step_ragged(id, 1, &[7, 9], &h).unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "{err}");
        assert!(matches!(u.step_ragged(id, 1, &[], &h), Err(Error::Shape(_))));
    }

    #[test]
    fn unrecoverable_when_no_replacement() {
        let swarm = FakeSwarm::new(&[("a", 0, 3), ("b", 3, 8)]);
        let mut s = InferenceSession::open(&swarm, cfg(8), shape(), 9).unwrap();
        s.prefill(Tensor::from_f32(&[1, 4, 4], &[0.0; 16])).unwrap();
        swarm.kill("b");
        let err = s.step(h1()).unwrap_err();
        assert!(matches!(err, Error::NoRoute(_)), "{err}");
    }

    #[test]
    fn transient_failure_bounded_retries() {
        let swarm = FakeSwarm::new(&[("a", 0, 8), ("a2", 0, 8)]);
        {
            let mut st = swarm.state.borrow_mut();
            st.servers[0].fail_next = 1; // one transient failure
            st.servers[1].fail_next = 0;
        }
        let mut s = InferenceSession::open(&swarm, cfg(8), shape(), 3).unwrap();
        let out = s.prefill(Tensor::from_f32(&[1, 4, 4], &[0.0; 16])).unwrap();
        assert!(out.as_f32().iter().all(|&v| v == 8.0));
        assert!(s.recoveries() <= 1);
    }

    /// Regression: a Busy admission rejection mid-chain-open must close
    /// the hops already opened, or their KV-page reservations leak on
    /// healthy servers (which have no session TTL).
    #[test]
    fn failed_open_closes_earlier_hops() {
        let swarm = FakeSwarm::new(&[("a", 0, 3), ("b", 3, 8)]);
        {
            let mut st = swarm.state.borrow_mut();
            st.servers[1].fail_open_next = 1;
        }
        let err = InferenceSession::open(&swarm, cfg(8), shape(), 4).unwrap_err();
        assert!(matches!(err, Error::Busy(_)), "{err}");
        let st = swarm.state.borrow();
        assert!(
            st.servers[0].sessions.is_empty(),
            "hop 'a' was opened before 'b' rejected — it must be closed again"
        );
    }

    #[test]
    fn open_fails_with_no_servers() {
        let swarm = FakeSwarm::new(&[]);
        assert!(matches!(
            InferenceSession::open(&swarm, cfg(8), shape(), 1),
            Err(Error::NoRoute(_))
        ));
    }

    #[test]
    fn chain_forward_stateless() {
        let swarm = FakeSwarm::new(&[("a", 0, 4), ("b", 4, 8)]);
        let route = cfg(8).route;
        let out = chain_forward(&swarm, &route, Tensor::from_f32(&[2, 3, 4], &[1.0; 24])).unwrap();
        assert!(out.as_f32().iter().all(|&v| v == 9.0));
    }

    /// A `moved:` redirect swaps the hop WITHOUT replaying: the target
    /// already holds the migrated KV, so the only traffic it sees is the
    /// step that triggered the redirect (after riding out the NotFound
    /// window while the migration push lands).
    #[test]
    fn moved_redirect_swaps_hop_without_replay() {
        let swarm = FakeSwarm::new(&[("a", 0, 3), ("b", 3, 8), ("b2", 3, 8)]);
        let mut s = InferenceSession::open(&swarm, cfg(8), shape(), 21).unwrap();
        s.prefill(Tensor::from_f32(&[1, 4, 4], &[0.0; 16])).unwrap();
        s.step(h1()).unwrap();
        s.step(h1()).unwrap();
        let hop1 = s.chain()[1].server;
        let (victim, target) =
            if hop1 == NodeId::from_name("b") { ("b", "b2") } else { ("b2", "b") };
        // drain victim -> target, with 2 NotFound replies of restore lag
        swarm.migrate(victim, target, 2);
        let out = s.step(h1()).unwrap();
        assert!(out.as_f32().iter().all(|&v| v == 8.0), "math unchanged");
        assert_eq!(s.recoveries(), 0, "redirect is not a recovery");
        assert_eq!(s.chain()[1].server, NodeId::from_name(target));
        // crucially NO replay: target served only the in-flight step
        // (cache_len 4), not the historical 2,3
        assert_eq!(swarm.steps_served(target, 21), vec![4]);
        // the session keeps working on the new chain
        s.step(h1()).unwrap();
        assert_eq!(swarm.steps_served(target, 21), vec![4, 5]);
    }

    /// When the redirect address doesn't resolve (e.g. the target is
    /// unknown to this client), the session falls back to replay-based
    /// recovery and still makes progress.
    #[test]
    fn moved_to_unknown_address_falls_back_to_recovery() {
        let swarm = FakeSwarm::new(&[("a", 0, 3), ("b", 3, 8), ("b2", 3, 8)]);
        let mut s = InferenceSession::open(&swarm, cfg(8), shape(), 22).unwrap();
        s.prefill(Tensor::from_f32(&[1, 4, 4], &[0.0; 16])).unwrap();
        s.step(h1()).unwrap();
        let hop1 = s.chain()[1].server;
        let (victim, replacement) =
            if hop1 == NodeId::from_name("b") { ("b", "b2") } else { ("b2", "b") };
        {
            // victim announces a move to an address nobody can resolve
            let vid = NodeId::from_name(victim);
            let mut st = swarm.state.borrow_mut();
            st.servers.iter_mut().find(|x| x.id == vid).unwrap().moved_to =
                Some("unknown-host:1".into());
        }
        let out = s.step(h1()).unwrap();
        assert!(out.as_f32().iter().all(|&v| v == 8.0));
        assert_eq!(s.recoveries(), 1, "unresolvable redirect went through replay");
        assert_eq!(s.chain()[1].server, NodeId::from_name(replacement));
        // replacement replayed step history (cache_lens 2) + the new step
        assert_eq!(swarm.steps_served(replacement, 22), vec![2, 3]);
    }

    /// `snapshot()` + `restore()` rebuilds the session on a fresh swarm
    /// with identical semantics: replayed history, matching row lens, and
    /// identical outputs afterwards.
    #[test]
    fn snapshot_restore_roundtrip_on_fresh_swarm() {
        let swarm = FakeSwarm::new(&[("a", 0, 3), ("b", 3, 8)]);
        let mut s = InferenceSession::open(&swarm, cfg(8), shape(), 31).unwrap();
        s.prefill(Tensor::from_f32(&[1, 4, 4], &[0.0; 16])).unwrap();
        s.step(h1()).unwrap();
        s.step(h1()).unwrap();
        let state = s.snapshot();
        assert_eq!(state.row_lens, vec![4]);
        assert_eq!(state.hops.len(), 2);
        // a completely different swarm (the old chain is gone)
        let swarm2 = FakeSwarm::new(&[("x", 0, 4), ("y", 4, 8)]);
        let mut r = InferenceSession::restore(&swarm2, cfg(8), state).unwrap();
        assert_eq!(r.row_lens(), &[4]);
        // the new hops replayed: prefill + both historical steps
        let st = swarm2.state.borrow();
        for srv in &st.servers {
            let (prefills, steps) = &srv.sessions[&31];
            assert_eq!(*prefills, 1, "restored hop ran the saved prefill");
            assert_eq!(steps, &vec![2, 3], "restored hop replayed step history");
        }
        drop(st);
        let out = r.step(h1()).unwrap();
        assert!(out.as_f32().iter().all(|&v| v == 8.0), "semantics preserved");
        assert_eq!(r.cache_len(), 5);
    }

    /// Restore fails cleanly (no leaked opens) when no chain covers a
    /// saved hop's span.
    #[test]
    fn restore_without_route_closes_opened_hops() {
        let swarm = FakeSwarm::new(&[("a", 0, 3), ("b", 3, 8)]);
        let mut s = InferenceSession::open(&swarm, cfg(8), shape(), 32).unwrap();
        s.prefill(Tensor::from_f32(&[1, 4, 4], &[0.0; 16])).unwrap();
        let state = s.snapshot();
        // the new swarm covers the first span only
        let swarm2 = FakeSwarm::new(&[("x", 0, 3)]);
        let err = InferenceSession::restore(&swarm2, cfg(8), state).unwrap_err();
        assert!(matches!(err, Error::NoRoute(_)), "{err}");
        let st = swarm2.state.borrow();
        assert!(
            st.servers[0].sessions.is_empty(),
            "hop opened before the NoRoute must be closed again"
        );
    }

    /// `close_row` fans out to every hop; defaulted transports are a
    /// no-op (legacy downgrade).
    #[test]
    fn close_row_reaches_every_hop() {
        let swarm = FakeSwarm::new(&[("a", 0, 3), ("b", 3, 8)]);
        let s = InferenceSession::open(&swarm, cfg(8), shape(), 33).unwrap();
        s.close_row(0);
        let st = swarm.state.borrow();
        for srv in &st.servers {
            assert_eq!(srv.rows_closed, vec![(33, 0)]);
        }
    }

    #[test]
    fn prop_recovery_preserves_pipeline_semantics() {
        // property: whatever single server we kill (with a replica
        // available), the pipeline output equals n_blocks added
        let mut rng = crate::config::Rng::new(0x5E5);
        for trial in 0..40 {
            let swarm = FakeSwarm::new(&[
                ("a", 0, 2),
                ("a2", 0, 2),
                ("b", 2, 5),
                ("b2", 2, 5),
                ("c", 5, 8),
                ("c2", 5, 8),
            ]);
            let mut s = InferenceSession::open(&swarm, cfg(8), shape(), trial).unwrap();
            s.prefill(Tensor::from_f32(&[1, 4, 4], &[0.0; 16])).unwrap();
            let n_steps = 1 + rng.usize_below(5);
            for _ in 0..n_steps {
                s.step(h1()).unwrap();
            }
            // kill one random in-chain server
            let hop = rng.usize_below(s.chain().len());
            let victim = s.chain()[hop].server;
            {
                let mut st = swarm.state.borrow_mut();
                st.servers.iter_mut().find(|x| x.id == victim).unwrap().alive = false;
            }
            let out = s.step(h1()).unwrap();
            assert!(
                out.as_f32().iter().all(|&v| v == 8.0),
                "trial {trial}: output corrupted after recovery"
            );
            assert_eq!(s.cache_len(), shape().prefix_len + n_steps + 1);
        }
    }
}
