//! Batch splitting for parallel forward passes (§3.2).
//!
//! "during fine-tuning one needs to process a batch of examples in
//! parallel. Here, clients can split their batches between multiple
//! servers using the algorithm from Ryabinin et al. (2023)" — i.e.
//! proportionally to measured per-server throughput, so the slowest
//! replica stops being the critical path.

/// Split `total` examples across replicas proportional to `rates`
/// (largest-remainder rounding; every replica with rate > 0 gets its
/// fair share, zero-rate replicas get nothing unless all are zero).
pub fn split_batch(total: usize, rates: &[f64]) -> Vec<usize> {
    let n = rates.len();
    if n == 0 {
        return vec![];
    }
    let sum: f64 = rates.iter().filter(|r| r.is_finite() && **r > 0.0).sum();
    if sum <= 0.0 {
        // degenerate: split evenly
        let base = total / n;
        let mut out = vec![base; n];
        for item in out.iter_mut().take(total % n) {
            *item += 1;
        }
        return out;
    }
    let mut out = vec![0usize; n];
    let mut rema: Vec<(f64, usize)> = Vec::with_capacity(n);
    let mut assigned = 0usize;
    for (i, &r) in rates.iter().enumerate() {
        let r = if r.is_finite() && r > 0.0 { r } else { 0.0 };
        let exact = total as f64 * r / sum;
        let fl = exact.floor() as usize;
        out[i] = fl;
        assigned += fl;
        rema.push((exact - fl as f64, i));
    }
    rema.sort_by(|a, b| b.0.total_cmp(&a.0));
    for k in 0..total - assigned {
        out[rema[k % n].1] += 1;
    }
    out
}

/// Predicted makespan of a split: max over replicas of examples/rate.
pub fn makespan(split: &[usize], rates: &[f64]) -> f64 {
    split
        .iter()
        .zip(rates)
        .map(|(&n, &r)| if n == 0 { 0.0 } else { n as f64 / r.max(1e-12) })
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proportional_split() {
        let s = split_batch(30, &[1.0, 2.0]);
        assert_eq!(s, vec![10, 20]);
    }

    #[test]
    fn sums_to_total_always() {
        let mut rng = crate::config::Rng::new(0xBA7);
        for _ in 0..300 {
            let n = 1 + rng.usize_below(8);
            let rates: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 10.0)).collect();
            let total = rng.usize_below(200);
            let s = split_batch(total, &rates);
            assert_eq!(s.iter().sum::<usize>(), total, "rates {rates:?}");
        }
    }

    #[test]
    fn zero_rate_replica_gets_nothing() {
        let s = split_batch(10, &[0.0, 1.0, 1.0]);
        assert_eq!(s[0], 0);
        assert_eq!(s.iter().sum::<usize>(), 10);
    }

    #[test]
    fn all_zero_rates_fall_back_to_even() {
        let s = split_batch(10, &[0.0, 0.0, 0.0]);
        assert_eq!(s.iter().sum::<usize>(), 10);
        assert!(s.iter().all(|&x| (3..=4).contains(&x)));
    }

    #[test]
    fn empty_replicas() {
        assert_eq!(split_batch(5, &[]), Vec::<usize>::new());
    }

    #[test]
    fn proportional_beats_even_on_makespan() {
        let rates = [4.0, 1.0];
        let prop = split_batch(100, &rates);
        let even = vec![50, 50];
        assert!(makespan(&prop, &rates) < makespan(&even, &rates));
    }

    #[test]
    fn prop_makespan_near_optimal() {
        // property: proportional split's makespan is within one
        // example-per-slowest-replica of the fractional lower bound
        let mut rng = crate::config::Rng::new(0xBA8);
        for _ in 0..200 {
            let n = 1 + rng.usize_below(6);
            let rates: Vec<f64> = (0..n).map(|_| rng.range_f64(0.5, 8.0)).collect();
            let total = 1 + rng.usize_below(500);
            let s = split_batch(total, &rates);
            let lower = total as f64 / rates.iter().sum::<f64>();
            let slowest = rates.iter().cloned().fold(f64::INFINITY, f64::min);
            assert!(
                makespan(&s, &rates) <= lower + 1.0 / slowest + 1e-9,
                "split {s:?} rates {rates:?}"
            );
        }
    }
}
