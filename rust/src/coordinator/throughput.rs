//! Server throughput estimation (§3.2): "Once the server has selected
//! its layers, it measures its own throughput (both network and compute)
//! and announces it to the distributed hash table."
//!
//! Throughput is requests/s for single-token inference over the hosted
//! span. The effective rate is the min of the compute rate and the
//! network rate (a server can't serve faster than it can receive/send
//! hidden states).

use crate::config::{DeviceProfile, NetworkProfile};
use crate::dht::NodeId;
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

/// Compute-side rate: steps/s for one decode over `n_blocks`.
pub fn compute_rate(device: &DeviceProfile, n_blocks: usize, bytes_per_block: u64) -> f64 {
    if n_blocks == 0 {
        return f64::INFINITY;
    }
    1.0 / device.decode_time(n_blocks, bytes_per_block, 1)
}

/// Network-side rate: hidden-state round trips/s through this server's
/// link (`hidden_bytes` in + out per step).
pub fn network_rate(net: &NetworkProfile, hidden_bytes: u64) -> f64 {
    let per_step = 2.0 * net.transfer_s(hidden_bytes) + net.rtt_s;
    1.0 / per_step
}

/// Announced throughput: the bottleneck of the two.
pub fn announced(
    device: &DeviceProfile,
    net: &NetworkProfile,
    n_blocks: usize,
    bytes_per_block: u64,
    hidden_bytes: u64,
) -> f64 {
    compute_rate(device, n_blocks, bytes_per_block)
        .min(network_rate(net, hidden_bytes))
}

/// Measured throughput from observed request latencies (real servers):
/// exponential moving average over per-request seconds.
#[derive(Debug, Clone)]
pub struct MeasuredThroughput {
    ema_latency_s: f64,
    alpha: f64,
    samples: u64,
}

impl Default for MeasuredThroughput {
    fn default() -> Self {
        Self::new()
    }
}

impl MeasuredThroughput {
    pub fn new() -> Self {
        MeasuredThroughput { ema_latency_s: 0.0, alpha: 0.2, samples: 0 }
    }

    pub fn observe(&mut self, latency_s: f64) {
        if self.samples == 0 {
            self.ema_latency_s = latency_s;
        } else {
            self.ema_latency_s =
                self.alpha * latency_s + (1.0 - self.alpha) * self.ema_latency_s;
        }
        self.samples += 1;
    }

    /// requests/s; 0 until the first observation.
    pub fn rate(&self) -> f64 {
        if self.samples == 0 || self.ema_latency_s == 0.0 {
            0.0
        } else {
            1.0 / self.ema_latency_s
        }
    }

    pub fn samples(&self) -> u64 {
        self.samples
    }
}

/// EWMA of *measured* per-hop step latency with a freshness stamp.
///
/// Unlike [`MeasuredThroughput`] (a server measuring itself), this is
/// the CLIENT's view of one remote hop, fed from `InferenceSession`
/// step clocks. The age lets routing decay stale measurements back
/// toward announced values (see
/// [`crate::coordinator::routing::ServerView::effective_step_s`]).
#[derive(Debug, Clone)]
pub struct StepEwma {
    ema_s: f64,
    samples: u64,
    last: Instant,
}

impl Default for StepEwma {
    fn default() -> Self {
        Self::new()
    }
}

impl StepEwma {
    const ALPHA: f64 = 0.2;

    pub fn new() -> Self {
        StepEwma { ema_s: 0.0, samples: 0, last: Instant::now() }
    }

    pub fn observe(&mut self, latency_s: f64) {
        if self.samples == 0 {
            self.ema_s = latency_s;
        } else {
            self.ema_s = Self::ALPHA * latency_s + (1.0 - Self::ALPHA) * self.ema_s;
        }
        self.samples += 1;
        self.last = Instant::now();
    }

    /// EWMA seconds; `None` until the first observation.
    pub fn value_s(&self) -> Option<f64> {
        (self.samples > 0).then_some(self.ema_s)
    }

    /// Seconds since the last observation (staleness).
    pub fn age_s(&self) -> f64 {
        self.last.elapsed().as_secs_f64()
    }

    pub fn samples(&self) -> u64 {
        self.samples
    }
}

/// Thread-safe registry of measured per-hop step latencies, keyed by
/// server id. One per swarm client: `InferenceSession` feeds it through
/// [`crate::coordinator::session::ChainClient::observe_step`], and
/// `discover()` stamps the resulting EWMAs onto the `ServerView`s so
/// `find_chain` can score candidate chains by estimated end-to-end
/// tokens/s instead of announced capacity alone.
#[derive(Default)]
pub struct MeasuredHops {
    inner: Mutex<HashMap<NodeId, StepEwma>>,
}

impl MeasuredHops {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn observe(&self, id: NodeId, latency_s: f64) {
        let mut m = self.inner.lock().unwrap();
        m.entry(id).or_default().observe(latency_s);
    }

    /// `(ewma_seconds, age_seconds)` for `id`, if any sample exists.
    pub fn get(&self, id: NodeId) -> Option<(f64, f64)> {
        let m = self.inner.lock().unwrap();
        let e = m.get(&id)?;
        Some((e.value_s()?, e.age_s()))
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy this registry's measurements onto `views` (the discover-time
    /// hook: announced telemetry stays, measurements overlay it).
    pub fn stamp(&self, views: &mut [crate::coordinator::routing::ServerView]) {
        for v in views.iter_mut() {
            if let Some((s, age)) = self.get(v.id) {
                v.measured_step_s = Some(s);
                v.measured_age_s = age;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profiles::bloom176b;

    #[test]
    fn compute_rate_scales_inverse_with_blocks() {
        let d = DeviceProfile::A100_80G;
        let r10 = compute_rate(&d, 10, bloom176b::BLOCK_BYTES_INT8);
        let r20 = compute_rate(&d, 20, bloom176b::BLOCK_BYTES_INT8);
        assert!(r10 > 1.8 * r20);
        assert_eq!(compute_rate(&d, 0, 1), f64::INFINITY);
    }

    #[test]
    fn network_binds_on_slow_links() {
        let d = DeviceProfile::A100_80G;
        let fast = NetworkProfile::GBIT_5MS;
        let slow = NetworkProfile {
            bandwidth_bps: 1e6, // 1 Mbit/s
            rtt_s: 0.3,
            jitter: 0.0,
            relay_extra_s: 0.0,
        };
        let hidden = (bloom176b::HIDDEN * 4) as u64;
        let a_fast = announced(&d, &fast, 24, bloom176b::BLOCK_BYTES_INT8, hidden);
        let a_slow = announced(&d, &slow, 24, bloom176b::BLOCK_BYTES_INT8, hidden);
        assert!(a_slow < a_fast);
        assert!(a_slow < network_rate(&slow, hidden) + 1e-9);
    }

    #[test]
    fn measured_ema_converges() {
        let mut m = MeasuredThroughput::new();
        assert_eq!(m.rate(), 0.0);
        for _ in 0..100 {
            m.observe(0.05);
        }
        assert!((m.rate() - 20.0).abs() < 0.5);
        // regime change is tracked
        for _ in 0..100 {
            m.observe(0.2);
        }
        assert!((m.rate() - 5.0).abs() < 0.5);
    }

    #[test]
    fn step_ewma_seeds_and_converges() {
        let mut e = StepEwma::new();
        assert_eq!(e.value_s(), None);
        e.observe(0.08);
        // first sample seeds (no cold-start bias)
        assert!((e.value_s().unwrap() - 0.08).abs() < 1e-12);
        for _ in 0..100 {
            e.observe(0.02);
        }
        assert!((e.value_s().unwrap() - 0.02).abs() < 1e-3);
        assert_eq!(e.samples(), 101);
        assert!(e.age_s() >= 0.0);
    }

    #[test]
    fn measured_hops_registry_stamps_views() {
        use crate::coordinator::routing::ServerView;
        let hops = MeasuredHops::new();
        assert!(hops.is_empty());
        let a = NodeId::from_name("a");
        let b = NodeId::from_name("b");
        hops.observe(a, 0.5);
        hops.observe(a, 0.5);
        assert_eq!(hops.len(), 1);
        assert!(hops.get(b).is_none());
        let (v, age) = hops.get(a).unwrap();
        assert!((v - 0.5).abs() < 1e-12);
        assert!(age >= 0.0);
        let mk = |id: NodeId| ServerView {
            id,
            start: 0,
            end: 4,
            latency_s: 0.01,
            bandwidth_bps: 1e9,
            span_compute_s: 0.1,
            queue_depth: 0,
            free_ratio: 1.0,
            prefix_fps: vec![],
            p50_step_us: 0,
            measured_step_s: None,
            measured_age_s: 0.0,
        };
        let mut views = vec![mk(a), mk(b)];
        hops.stamp(&mut views);
        assert!((views[0].measured_step_s.unwrap() - 0.5).abs() < 1e-12);
        assert_eq!(views[1].measured_step_s, None);
    }
}
