//! Server throughput estimation (§3.2): "Once the server has selected
//! its layers, it measures its own throughput (both network and compute)
//! and announces it to the distributed hash table."
//!
//! Throughput is requests/s for single-token inference over the hosted
//! span. The effective rate is the min of the compute rate and the
//! network rate (a server can't serve faster than it can receive/send
//! hidden states).

use crate::config::{DeviceProfile, NetworkProfile};

/// Compute-side rate: steps/s for one decode over `n_blocks`.
pub fn compute_rate(device: &DeviceProfile, n_blocks: usize, bytes_per_block: u64) -> f64 {
    if n_blocks == 0 {
        return f64::INFINITY;
    }
    1.0 / device.decode_time(n_blocks, bytes_per_block, 1)
}

/// Network-side rate: hidden-state round trips/s through this server's
/// link (`hidden_bytes` in + out per step).
pub fn network_rate(net: &NetworkProfile, hidden_bytes: u64) -> f64 {
    let per_step = 2.0 * net.transfer_s(hidden_bytes) + net.rtt_s;
    1.0 / per_step
}

/// Announced throughput: the bottleneck of the two.
pub fn announced(
    device: &DeviceProfile,
    net: &NetworkProfile,
    n_blocks: usize,
    bytes_per_block: u64,
    hidden_bytes: u64,
) -> f64 {
    compute_rate(device, n_blocks, bytes_per_block)
        .min(network_rate(net, hidden_bytes))
}

/// Measured throughput from observed request latencies (real servers):
/// exponential moving average over per-request seconds.
#[derive(Debug, Clone)]
pub struct MeasuredThroughput {
    ema_latency_s: f64,
    alpha: f64,
    samples: u64,
}

impl Default for MeasuredThroughput {
    fn default() -> Self {
        Self::new()
    }
}

impl MeasuredThroughput {
    pub fn new() -> Self {
        MeasuredThroughput { ema_latency_s: 0.0, alpha: 0.2, samples: 0 }
    }

    pub fn observe(&mut self, latency_s: f64) {
        if self.samples == 0 {
            self.ema_latency_s = latency_s;
        } else {
            self.ema_latency_s =
                self.alpha * latency_s + (1.0 - self.alpha) * self.ema_latency_s;
        }
        self.samples += 1;
    }

    /// requests/s; 0 until the first observation.
    pub fn rate(&self) -> f64 {
        if self.samples == 0 || self.ema_latency_s == 0.0 {
            0.0
        } else {
            1.0 / self.ema_latency_s
        }
    }

    pub fn samples(&self) -> u64 {
        self.samples
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profiles::bloom176b;

    #[test]
    fn compute_rate_scales_inverse_with_blocks() {
        let d = DeviceProfile::A100_80G;
        let r10 = compute_rate(&d, 10, bloom176b::BLOCK_BYTES_INT8);
        let r20 = compute_rate(&d, 20, bloom176b::BLOCK_BYTES_INT8);
        assert!(r10 > 1.8 * r20);
        assert_eq!(compute_rate(&d, 0, 1), f64::INFINITY);
    }

    #[test]
    fn network_binds_on_slow_links() {
        let d = DeviceProfile::A100_80G;
        let fast = NetworkProfile::GBIT_5MS;
        let slow = NetworkProfile {
            bandwidth_bps: 1e6, // 1 Mbit/s
            rtt_s: 0.3,
            jitter: 0.0,
            relay_extra_s: 0.0,
        };
        let hidden = (bloom176b::HIDDEN * 4) as u64;
        let a_fast = announced(&d, &fast, 24, bloom176b::BLOCK_BYTES_INT8, hidden);
        let a_slow = announced(&d, &slow, 24, bloom176b::BLOCK_BYTES_INT8, hidden);
        assert!(a_slow < a_fast);
        assert!(a_slow < network_rate(&slow, hidden) + 1e-9);
    }

    #[test]
    fn measured_ema_converges() {
        let mut m = MeasuredThroughput::new();
        assert_eq!(m.rate(), 0.0);
        for _ in 0..100 {
            m.observe(0.05);
        }
        assert!((m.rate() - 20.0).abs() < 0.5);
        // regime change is tracked
        for _ in 0..100 {
            m.observe(0.2);
        }
        assert!((m.rate() - 5.0).abs() < 0.5);
    }
}
