//! Server-side load balancing (§3.2).
//!
//! "First, we ensure that servers are distributed evenly among
//! Transformer blocks. Formally, servers maximize the total model
//! throughput by choosing the blocks with the worst throughput and
//! eliminating potential bottlenecks. [...] When a new server joins, it
//! uses this information to identify an interval of blocks that contains
//! most blocks with the worst throughput. This interval is always
//! contiguous. [...] all nodes periodically check if launching a
//! rebalancing procedure would significantly improve the overall
//! throughput."
//!
//! All logic here is pure: inputs are per-block throughput sums
//! ([`BlockCoverage`]), outputs are spans/moves — so the same code runs
//! in real servers, the simulator, and property tests.

/// Per-block total announced throughput (sum over servers hosting it).
#[derive(Debug, Clone, PartialEq)]
pub struct BlockCoverage {
    pub per_block: Vec<f64>,
}

impl BlockCoverage {
    pub fn new(n_blocks: usize) -> Self {
        BlockCoverage { per_block: vec![0.0; n_blocks] }
    }

    pub fn from_entries<'a>(
        n_blocks: usize,
        entries: impl Iterator<Item = &'a crate::dht::ServerEntry>,
    ) -> Self {
        let mut c = Self::new(n_blocks);
        for e in entries {
            for b in e.start..e.end.min(n_blocks as u32) {
                c.per_block[b as usize] += e.throughput as f64;
            }
        }
        c
    }

    /// Like [`Self::from_entries`], but discounts each server's announced
    /// throughput by its KV-pool occupancy: a server whose pool is nearly
    /// full cannot admit new sessions, so counting its full throughput
    /// would hide an admission bottleneck from the rebalancer. A server
    /// at occupancy `o` contributes `throughput * (1 - o/2)` — half
    /// weight when completely full (it still serves its live sessions),
    /// full weight when idle or when it predates the v2 announcement.
    pub fn from_entries_load_aware<'a>(
        n_blocks: usize,
        entries: impl Iterator<Item = &'a crate::dht::ServerEntry>,
    ) -> Self {
        let mut c = Self::new(n_blocks);
        for e in entries {
            let discount = 1.0 - (1.0 - e.free_ratio()) / 2.0;
            for b in e.start..e.end.min(n_blocks as u32) {
                c.per_block[b as usize] += e.throughput as f64 * discount;
            }
        }
        c
    }

    pub fn add_span(&mut self, span: std::ops::Range<usize>, throughput: f64) {
        for b in span {
            self.per_block[b] += throughput;
        }
    }

    pub fn remove_span(&mut self, span: std::ops::Range<usize>, throughput: f64) {
        for b in span {
            self.per_block[b] = (self.per_block[b] - throughput).max(0.0);
        }
    }

    pub fn n_blocks(&self) -> usize {
        self.per_block.len()
    }
}

/// Total model throughput: the pipeline is bottlenecked by its weakest
/// block (every request visits every block).
pub fn swarm_throughput(cov: &BlockCoverage) -> f64 {
    cov.per_block.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Span a joining server should host: the contiguous `capacity`-length
/// interval covering the most bottleneck-valued blocks; ties broken by
/// lowest total coverage (then leftmost, for determinism).
pub fn choose_join_span(cov: &BlockCoverage, capacity: usize) -> std::ops::Range<usize> {
    let n = cov.n_blocks();
    let len = capacity.min(n).max(1);
    let worst = swarm_throughput(cov);
    let eps = 1e-9;
    let mut best_start = 0usize;
    let mut best_key = (usize::MAX, f64::INFINITY);
    // O(n * len) scan is fine at n<=70-ish; a sliding window would be
    // O(n) but obscures the tie-breaking rule.
    for start in 0..=(n - len) {
        let window = &cov.per_block[start..start + len];
        let n_worst = window.iter().filter(|&&t| t <= worst + eps).count();
        let total: f64 = window.iter().sum();
        // maximize n_worst, then minimize total coverage
        let key = (usize::MAX - n_worst, total);
        if key < best_key {
            best_key = key;
            best_start = start;
        }
    }
    best_start..best_start + len
}

/// A proposed server move.
#[derive(Debug, Clone, PartialEq)]
pub struct RebalanceMove {
    pub server_idx: usize,
    pub from: std::ops::Range<usize>,
    pub to: std::ops::Range<usize>,
    pub gain: f64,
}

/// Check whether moving any single server to its greedily-best span
/// improves total throughput by at least `min_gain_ratio` (paper:
/// "significantly improve"). Returns the best such move.
///
/// `servers`: (span, announced throughput) per live server.
pub fn plan_rebalance(
    n_blocks: usize,
    servers: &[(std::ops::Range<usize>, f64)],
    min_gain_ratio: f64,
) -> Option<RebalanceMove> {
    let mut cov = BlockCoverage::new(n_blocks);
    for (span, t) in servers {
        cov.add_span(span.clone(), *t);
    }
    let current = swarm_throughput(&cov);
    let mut best: Option<RebalanceMove> = None;
    for (i, (span, t)) in servers.iter().enumerate() {
        // hypothetically remove this server, re-place it greedily
        let mut without = cov.clone();
        without.remove_span(span.clone(), *t);
        let capacity = span.len();
        let new_span = choose_join_span(&without, capacity);
        let mut with_new = without.clone();
        with_new.add_span(new_span.clone(), *t);
        let new_total = swarm_throughput(&with_new);
        let gain = new_total - current;
        let significant = if current <= 0.0 {
            gain > 0.0
        } else {
            gain / current >= min_gain_ratio
        };
        if significant && new_span != *span {
            let better_than_best = best.as_ref().map(|b| gain > b.gain).unwrap_or(true);
            if better_than_best {
                best = Some(RebalanceMove {
                    server_idx: i,
                    from: span.clone(),
                    to: new_span,
                    gain,
                });
            }
        }
    }
    best
}

/// Run `plan_rebalance` to a fixed point (bounded rounds), applying each
/// move — models the paper's "they switch layers until the throughput
/// becomes near-optimal".
pub fn rebalance_to_fixpoint(
    n_blocks: usize,
    servers: &mut Vec<(std::ops::Range<usize>, f64)>,
    min_gain_ratio: f64,
    max_rounds: usize,
) -> usize {
    let mut moves = 0;
    for _ in 0..max_rounds {
        match plan_rebalance(n_blocks, servers, min_gain_ratio) {
            Some(mv) => {
                servers[mv.server_idx].0 = mv.to;
                moves += 1;
            }
            None => break,
        }
    }
    moves
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn join_targets_uncovered_gap() {
        let mut cov = BlockCoverage::new(12);
        cov.add_span(0..6, 1.0); // first half covered
        let span = choose_join_span(&cov, 6);
        assert_eq!(span, 6..12, "new server must take the empty half");
    }

    #[test]
    fn join_prefers_weakest_window() {
        let mut cov = BlockCoverage::new(9);
        cov.add_span(0..9, 1.0);
        cov.add_span(0..3, 5.0); // left strong
        cov.add_span(6..9, 2.0); // right medium; middle weakest
        let span = choose_join_span(&cov, 3);
        assert_eq!(span, 3..6);
    }

    #[test]
    fn join_capacity_larger_than_model() {
        let cov = BlockCoverage::new(4);
        let span = choose_join_span(&cov, 100);
        assert_eq!(span, 0..4);
    }

    #[test]
    fn throughput_is_min_over_blocks() {
        let mut cov = BlockCoverage::new(4);
        cov.add_span(0..4, 2.0);
        cov.add_span(1..2, 3.0);
        assert_eq!(swarm_throughput(&cov), 2.0);
        cov.remove_span(3..4, 2.0);
        assert_eq!(swarm_throughput(&cov), 0.0);
    }

    #[test]
    fn rebalance_closes_gap_after_mass_departure() {
        // paper: "if all peers serving certain blocks suddenly leave the
        // system, this procedure quickly redistributes the remaining
        // resources to close the emerged gaps"
        let n = 12;
        // 4 servers, 2 stacked on 0..6, 2 stacked on 6..12 — then the two
        // on 6..12 "leave", leaving double coverage left and none right:
        let mut servers = vec![(0..6, 1.0), (0..6, 1.0)];
        assert_eq!(
            swarm_throughput(&BlockCoverage::from_spans(n, &servers)),
            0.0
        );
        let moves = rebalance_to_fixpoint(n, &mut servers, 0.05, 10);
        assert!(moves >= 1);
        let total = swarm_throughput(&BlockCoverage::from_spans(n, &servers));
        assert!(total > 0.0, "gap closed: {servers:?}");
    }

    #[test]
    fn load_aware_coverage_discounts_full_pools() {
        use crate::dht::{NodeId, ServerEntry};
        let mk = |free: u32, total: u32| ServerEntry {
            server: NodeId::from_name("s"),
            start: 0,
            end: 4,
            throughput: 2.0,
            free_pages: free,
            total_pages: total,
            batch_width: 8,
            prefix_fps: vec![],
            p50_step_us: 0,
            queue_depth: 0,
            sessions_active: 0,
        };
        let idle = [mk(100, 100)];
        let full = [mk(0, 100)];
        let legacy = [mk(0, 0)];
        let t = |es: &[ServerEntry]| {
            swarm_throughput(&BlockCoverage::from_entries_load_aware(4, es.iter()))
        };
        assert_eq!(t(&idle), 2.0);
        assert_eq!(t(&full), 1.0, "a full pool counts at half weight");
        assert_eq!(t(&legacy), 2.0, "legacy entries are not penalized");
        // the plain variant ignores occupancy entirely
        let plain = swarm_throughput(&BlockCoverage::from_entries(4, full.iter()));
        assert_eq!(plain, 2.0);
    }

    #[test]
    fn rebalance_noop_when_balanced() {
        let servers = vec![(0..6, 1.0), (6..12, 1.0)];
        assert!(plan_rebalance(12, &servers, 0.05).is_none());
    }

    #[test]
    fn rebalance_requires_significant_gain() {
        // moving would only marginally improve -> below threshold, no move
        let servers = vec![(0..6, 1.0), (0..6, 0.01), (6..12, 1.0)];
        // moving server 1 to 6..12 changes min from 1.0 to 1.0 (gain 0)
        assert!(plan_rebalance(12, &servers, 0.05).is_none());
    }

    // --- edge cases (ISSUE 9 satellite) --------------------------------

    #[test]
    fn single_server_swarm_never_moves() {
        // a lone server IS the swarm: any move keeps min-coverage equal
        // (its own throughput over `capacity` blocks) or makes it worse,
        // so the planner must stay put whatever span it currently holds
        for span in [0..4, 2..6, 8..12] {
            let servers = vec![(span.clone(), 1.5)];
            assert_eq!(plan_rebalance(12, &servers, 0.05), None, "span {span:?} moved");
        }
        // ...including a lone server covering the whole model
        assert_eq!(plan_rebalance(8, &[(0..8, 2.0)], 0.0), None);
    }

    #[test]
    fn capacity_smaller_than_any_gap_still_greedy() {
        // 2-block capacity vs a 6-block hole: no placement fixes the
        // swarm (min stays 0), but the greedy pick must still land
        // INSIDE the hole (most bottleneck-valued blocks), leftmost on
        // ties — not thrash or panic
        let mut cov = BlockCoverage::new(12);
        cov.add_span(0..6, 3.0); // hole is 6..12
        let span = choose_join_span(&cov, 2);
        assert_eq!(span, 6..8, "2-block join must take the leftmost hole window");
        // a planner round on the same shape: the only server is pinned
        // at capacity 6 < hole-width 6 + covered 6, moving it just moves
        // the hole — gain is 0, so no move is proposed
        let servers = vec![(0..6, 3.0)];
        assert_eq!(plan_rebalance(12, &servers, 0.05), None);
    }

    #[test]
    fn all_blocks_covered_noop() {
        // healthy tiling (uniform coverage): nothing to gain, planner
        // must return None even at a zero gain threshold
        let servers = vec![(0..4, 1.0), (4..8, 1.0), (8..12, 1.0)];
        assert_eq!(plan_rebalance(12, &servers, 0.0), None);
        let mut owned = servers.clone();
        assert_eq!(rebalance_to_fixpoint(12, &mut owned, 0.0, 16), 0);
        assert_eq!(owned, servers, "fixpoint must not disturb a balanced swarm");
    }

    #[test]
    fn greedy_pick_deterministic_under_ties() {
        // a fully symmetric coverage: every window ties on (n_worst,
        // total), so the tie-break must be "leftmost", reproducibly
        let cov = BlockCoverage::new(10);
        for _ in 0..5 {
            assert_eq!(choose_join_span(&cov, 4), 0..4);
        }
        // same symmetry through the planner: identical inputs produce
        // the identical move, run after run (servers don't thrash on
        // ties because everyone computes the same answer)
        let servers = vec![(0..5, 1.0), (0..5, 1.0)];
        let first = plan_rebalance(10, &servers, 0.05);
        assert!(first.is_some(), "half-covered swarm must move");
        for _ in 0..5 {
            assert_eq!(plan_rebalance(10, &servers, 0.05), first);
        }
        assert_eq!(first.unwrap().to, 5..10);
    }

    impl BlockCoverage {
        pub(crate) fn from_spans(n: usize, servers: &[(std::ops::Range<usize>, f64)]) -> Self {
            let mut c = BlockCoverage::new(n);
            for (s, t) in servers {
                c.add_span(s.clone(), *t);
            }
            c
        }
    }

    // --- property tests (in-tree harness: deterministic PRNG sweeps) ---

    #[test]
    fn prop_join_never_decreases_throughput() {
        let mut rng = crate::config::Rng::new(0xB41);
        for _ in 0..200 {
            let n = 2 + rng.usize_below(30);
            let mut cov = BlockCoverage::new(n);
            for _ in 0..rng.usize_below(6) {
                let a = rng.usize_below(n);
                let b = (a + 1 + rng.usize_below(n - a)).min(n);
                cov.add_span(a..b, rng.range_f64(0.1, 5.0));
            }
            let before = swarm_throughput(&cov);
            let cap = 1 + rng.usize_below(n);
            let span = choose_join_span(&cov, cap);
            assert!(span.end <= n && !span.is_empty());
            let mut after = cov.clone();
            after.add_span(span, rng.range_f64(0.1, 5.0));
            assert!(swarm_throughput(&after) >= before - 1e-12);
        }
    }

    #[test]
    fn prop_join_span_contains_a_bottleneck_block() {
        let mut rng = crate::config::Rng::new(0xB42);
        for _ in 0..200 {
            let n = 2 + rng.usize_below(40);
            let mut cov = BlockCoverage::new(n);
            for _ in 0..1 + rng.usize_below(5) {
                let a = rng.usize_below(n);
                let b = (a + 1 + rng.usize_below(n - a)).min(n);
                cov.add_span(a..b, rng.range_f64(0.1, 5.0));
            }
            let cap = 1 + rng.usize_below(n);
            let worst = swarm_throughput(&cov);
            let span = choose_join_span(&cov, cap);
            assert!(
                cov.per_block[span.clone()].iter().any(|&t| t <= worst + 1e-9),
                "span {span:?} must cover at least one bottleneck block"
            );
        }
    }

    #[test]
    fn prop_rebalance_fixpoint_monotone() {
        let mut rng = crate::config::Rng::new(0xB43);
        for _ in 0..100 {
            let n = 4 + rng.usize_below(20);
            let mut servers = Vec::new();
            for _ in 0..2 + rng.usize_below(5) {
                let cap = 1 + rng.usize_below(n);
                let start = rng.usize_below(n - cap + 1);
                servers.push((start..start + cap, rng.range_f64(0.2, 3.0)));
            }
            let before = swarm_throughput(&BlockCoverage::from_spans(n, &servers));
            rebalance_to_fixpoint(n, &mut servers, 0.05, 20);
            let after = swarm_throughput(&BlockCoverage::from_spans(n, &servers));
            assert!(
                after >= before - 1e-12,
                "rebalancing must never lose throughput ({before} -> {after})"
            );
        }
    }
}
