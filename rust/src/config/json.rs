//! Minimal JSON parser — substrate module.
//!
//! The build environment is fully offline with no serde_json in the
//! vendored crate set, so the manifest/config loader ships its own
//! RFC 8259 subset parser: objects, arrays, strings (with escapes),
//! numbers, booleans, null. No serializer bells: `Value::render` emits
//! compact JSON for the few places we write configs/ledgers back out.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(src: &str) -> Result<Value> {
        let mut p = Parser { b: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(Error::Parse(format!("trailing data at byte {}", p.pos)));
        }
        Ok(v)
    }

    // --- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m
                .get(key)
                .ok_or_else(|| Error::Parse(format!("missing key {key:?}"))),
            _ => Err(Error::Parse(format!("not an object (want key {key:?})"))),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key).filter(|v| !matches!(v, Value::Null)),
            _ => None,
        }
    }

    pub fn obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => Err(Error::Parse("expected object".into())),
        }
    }

    pub fn arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => Err(Error::Parse("expected array".into())),
        }
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => Err(Error::Parse("expected string".into())),
        }
    }

    pub fn f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => Err(Error::Parse("expected number".into())),
        }
    }

    pub fn usize(&self) -> Result<usize> {
        Ok(self.f64()? as usize)
    }

    pub fn u64(&self) -> Result<u64> {
        Ok(self.f64()? as u64)
    }

    pub fn bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => Err(Error::Parse("expected bool".into())),
        }
    }

    /// Shapes etc.: array of numbers -> Vec<usize>.
    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.arr()?.iter().map(|v| v.usize()).collect()
    }

    // --- compact serializer -------------------------------------------------

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.render_into(&mut s);
        s
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Value::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Value::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.render_into(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Value::Str(k.clone()).render_into(out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.pos)
            .copied()
            .ok_or_else(|| Error::Parse("unexpected end of JSON".into()))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? == c {
            self.pos += 1;
            Ok(())
        } else {
            Err(Error::Parse(format!(
                "expected {:?} at byte {}, found {:?}",
                c as char, self.pos, self.peek()? as char
            )))
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(Error::Parse(format!("bad literal at byte {}", self.pos)))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Value::Obj(m));
                }
                c => {
                    return Err(Error::Parse(format!(
                        "expected ',' or '}}' at byte {}, found {:?}",
                        self.pos, c as char
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Value::Arr(v));
                }
                c => {
                    return Err(Error::Parse(format!(
                        "expected ',' or ']' at byte {}, found {:?}",
                        self.pos, c as char
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| Error::Parse("bad \\u escape".into()))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| Error::Parse("bad \\u escape".into()))?,
                                16,
                            )
                            .map_err(|_| Error::Parse("bad \\u escape".into()))?;
                            self.pos += 4;
                            // (surrogate pairs unsupported — artifacts never emit them)
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| Error::Parse("bad codepoint".into()))?,
                            );
                        }
                        other => {
                            return Err(Error::Parse(format!(
                                "bad escape \\{}",
                                other as char
                            )))
                        }
                    }
                }
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xf0 {
                            4
                        } else if c >= 0xe0 {
                            3
                        } else {
                            2
                        };
                        let bytes = self
                            .b
                            .get(start..start + len)
                            .ok_or_else(|| Error::Parse("truncated utf8".into()))?;
                        s.push_str(
                            std::str::from_utf8(bytes)
                                .map_err(|_| Error::Parse("bad utf8".into()))?,
                        );
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|_| Error::Parse("bad number".into()))?;
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| Error::Parse(format!("bad number {s:?} at byte {start}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("42").unwrap().f64().unwrap(), 42.0);
        assert_eq!(Value::parse("-1.5e3").unwrap().f64().unwrap(), -1500.0);
        assert_eq!(Value::parse("true").unwrap().bool().unwrap(), true);
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("\"hi\\n\"").unwrap().str().unwrap(), "hi\n");
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().arr().unwrap()[2]
                .get("b")
                .unwrap()
                .str()
                .unwrap(),
            "c"
        );
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Value::parse(r#""éA""#).unwrap();
        assert_eq!(v.str().unwrap(), "éA");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Value::parse("\"héllo — ≈\"").unwrap();
        assert_eq!(v.str().unwrap(), "héllo — ≈");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("{\"a\" 1}").is_err());
        assert!(Value::parse("12 34").is_err());
        assert!(Value::parse("").is_err());
    }

    #[test]
    fn render_roundtrip() {
        let src = r#"{"a":[1,2.5,"x\"y"],"b":true,"c":null}"#;
        let v = Value::parse(src).unwrap();
        let out = v.render();
        assert_eq!(Value::parse(&out).unwrap(), v);
    }

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"entries":{"e":{"shape":[1,128,512],"dtype":"f32"}}}"#;
        let v = Value::parse(src).unwrap();
        assert_eq!(
            v.get("entries").unwrap().get("e").unwrap().get("shape").unwrap().usize_vec().unwrap(),
            vec![1, 128, 512]
        );
    }
}
