//! Deterministic PRNG substrate (xoshiro256**): workload generation,
//! simulator jitter, and the in-tree property-testing harness all need
//! reproducible randomness, and the offline crate set has no `rand`.

/// xoshiro256** by Blackman & Vigna (public domain reference impl).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 to fill the state from one word
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Lemire's multiply-shift with rejection for exactness
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64() < p_true
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_below(xs.len())]
    }

    /// Export the raw generator state — the session-durability snapshot
    /// path serializes this so a restored sampler continues the exact
    /// draw sequence it would have produced uninterrupted.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from an exported [`Self::state`].
    pub fn from_state(s: [u64; 4]) -> Self {
        Rng { s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02, "mean near 0.5");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05);
        assert!((var - 1.0).abs() < 0.1);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
