//! Device / network / swarm profiles — the knobs behind every Table-3 row.
//!
//! The paper benchmarks BLOOM-176B on hardware we do not have (A100s,
//! consumer GPUs spread over two continents). Per DESIGN.md
//! §Substitutions, the simulator reproduces those rows with a calibrated
//! analytic compute model + the deterministic network simulator, while
//! the end-to-end examples run *real* PJRT compute on BLOOM-mini.
//!
//! Compute model (per server, per inference step over `n` blocks at
//! batch `b` tokens):
//!
//! ```text
//! decode:  t = overhead + n * block_bytes(precision) / mem_bw
//! prefill: t = overhead + n * tokens * flops_per_token_block / flops_eff
//! ```
//!
//! Single-token decode is memory-bound (each step streams every weight
//! byte once); large-batch forward is compute-bound. `flops_eff` is the
//! *achieved* rate (peak x utilization), calibrated so the 3x-A100 row
//! lands near the paper's 1.7 steps/s and 250 tok/s — all other rows
//! then follow from hardware ratios, which is exactly the reproduction
//! target (shape, not absolute numbers).

/// One accelerator model hosting Petals blocks.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// GPU memory available for blocks, bytes.
    pub mem_bytes: u64,
    /// Effective memory bandwidth, bytes/s (decode path).
    pub mem_bw: f64,
    /// Achieved dense-matmul rate, FLOP/s (prefill path).
    pub flops_eff: f64,
    /// Fixed per-request overhead, seconds (kernel launch, framework,
    /// (de)quantization of activations).
    pub overhead_s: f64,
}

impl DeviceProfile {
    pub const A100_80G: DeviceProfile = DeviceProfile {
        name: "A100-80GB",
        mem_bytes: 80 * GB,
        mem_bw: 320e9, // achieved effective rate incl. framework (calibrated)
        flops_eff: 100e12,
        overhead_s: 0.004,
    };

    /// One quarter of an A100 (the paper partitions each A100 into
    /// "3 large and 1 small" virtual servers; we model 4 equal quarters,
    /// which matches aggregate capacity). Memory bandwidth stays near the
    /// full card's: the partitions time-share the same HBM, and the
    /// paper's 12-virtual row (1.24 steps/s at 1 Gbit) implies ~11
    /// ms/block — only ~1.4x the physical-A100 block time.
    pub const VIRTUAL_QUARTER_A100: DeviceProfile = DeviceProfile {
        name: "virtual-A100/4",
        mem_bytes: 20 * GB,
        mem_bw: 220e9,
        flops_eff: 25e12,
        overhead_s: 0.004,
    };

    pub const RTX_3060: DeviceProfile = DeviceProfile {
        name: "RTX-3060",
        mem_bytes: 12 * GB,
        mem_bw: 58e9, // 360 GB/s peak scaled by the same achieved ratio
        flops_eff: 9e12,
        overhead_s: 0.005,
    };

    pub const RTX_2080TI: DeviceProfile = DeviceProfile {
        name: "RTX-2080Ti",
        mem_bytes: 11 * GB,
        mem_bw: 99e9,
        flops_eff: 10e12,
        overhead_s: 0.005,
    };

    pub const RTX_3090: DeviceProfile = DeviceProfile {
        name: "RTX-3090",
        mem_bytes: 24 * GB,
        mem_bw: 150e9,
        flops_eff: 25e12,
        overhead_s: 0.005,
    };

    pub const A4000: DeviceProfile = DeviceProfile {
        name: "A4000",
        mem_bytes: 16 * GB,
        mem_bw: 72e9,
        flops_eff: 14e12,
        overhead_s: 0.005,
    };

    pub const A5000: DeviceProfile = DeviceProfile {
        name: "A5000",
        mem_bytes: 24 * GB,
        mem_bw: 123e9,
        flops_eff: 20e12,
        overhead_s: 0.005,
    };

    /// Blocks this device can host at `bytes_per_block` (minus ~1 GB of
    /// runtime overhead).
    pub fn capacity_blocks(&self, bytes_per_block: u64) -> usize {
        let usable = self.mem_bytes.saturating_sub(GB);
        (usable / bytes_per_block.max(1)) as usize
    }

    /// Seconds for one single-token decode step over `n_blocks`.
    pub fn decode_time(&self, n_blocks: usize, bytes_per_block: u64, batch: usize) -> f64 {
        // The weight stream is shared across the batch; activations are
        // negligible next to weights for batch <= 64.
        let weight_t = n_blocks as f64 * bytes_per_block as f64 / self.mem_bw;
        let batch_t = 0.02e-3 * batch.saturating_sub(1) as f64 * n_blocks as f64;
        self.overhead_s + weight_t + batch_t
    }

    /// Seconds for a parallel forward of `tokens` through `n_blocks`.
    ///
    /// Small token counts do not saturate the matrix units: achieved
    /// FLOP/s ramps as tokens/(tokens + 384) (half-saturation at 384
    /// tokens, matching the paper's 3xA100 forward column where 128
    /// tokens reach ~25% of large-batch throughput).
    pub fn forward_time(&self, n_blocks: usize, tokens: usize, flops_per_token_block: f64) -> f64 {
        let sat = tokens as f64 / (tokens as f64 + 384.0);
        let achieved = self.flops_eff * sat;
        let compute = n_blocks as f64 * tokens as f64 * flops_per_token_block / achieved;
        self.overhead_s + compute
    }
}

pub const GB: u64 = 1 << 30;
pub const MBIT: f64 = 1e6;
pub const GBIT: f64 = 1e9;

/// Point-to-point network conditions (paper §3.3 emulates these with
/// wondershaper; we inject them in the simulator).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkProfile {
    /// Bidirectional bandwidth, bits/s.
    pub bandwidth_bps: f64,
    /// Round-trip latency, seconds.
    pub rtt_s: f64,
    /// Relative jitter on per-message latency (0.0 = deterministic).
    pub jitter: f64,
    /// Extra one-way latency for NAT/firewall relay hops (libp2p circuit
    /// relay in the paper; 4 of the 14 real servers needed it).
    pub relay_extra_s: f64,
}

impl NetworkProfile {
    pub const GBIT_5MS: NetworkProfile = NetworkProfile {
        bandwidth_bps: 1.0 * GBIT,
        rtt_s: 0.005,
        jitter: 0.0,
        relay_extra_s: 0.0,
    };

    pub const MBIT100_5MS: NetworkProfile = NetworkProfile {
        bandwidth_bps: 100.0 * MBIT,
        rtt_s: 0.005,
        jitter: 0.0,
        relay_extra_s: 0.0,
    };

    pub const MBIT100_100MS: NetworkProfile = NetworkProfile {
        bandwidth_bps: 100.0 * MBIT,
        rtt_s: 0.100,
        jitter: 0.0,
        relay_extra_s: 0.0,
    };

    /// One-way propagation delay.
    pub fn one_way_s(&self) -> f64 {
        self.rtt_s / 2.0 + self.relay_extra_s
    }

    /// Seconds to push `bytes` through the link (serialization delay).
    pub fn transfer_s(&self, bytes: u64) -> f64 {
        (bytes as f64 * 8.0) / self.bandwidth_bps
    }

    /// Total one-way message time.
    pub fn message_s(&self, bytes: u64) -> f64 {
        self.one_way_s() + self.transfer_s(bytes)
    }
}

/// One server in a swarm scenario.
#[derive(Debug, Clone)]
pub struct ServerSpec {
    pub device: DeviceProfile,
    /// Link from/to this server (overrides the swarm default if set).
    pub net: Option<NetworkProfile>,
    /// Server is behind a NAT and reachable only via relay.
    pub relayed: bool,
}

/// Client-side hardware (paper: 8 CPU cores, no GPU): embedding lookup +
/// LM head per step.
#[derive(Debug, Clone, Copy)]
pub struct ClientProfile {
    pub step_overhead_s: f64,
}

impl Default for ClientProfile {
    fn default() -> Self {
        // embedding + lm head of BLOOM-176B on 8 CPU cores ~ 15 ms
        ClientProfile { step_overhead_s: 0.015 }
    }
}

/// A full swarm scenario: the model being served, who serves it, and the
/// ambient network.
#[derive(Debug, Clone)]
pub struct SwarmProfile {
    pub name: String,
    pub n_blocks: usize,
    pub bytes_per_block: u64,
    pub flops_per_token_block: f64,
    pub hidden: usize,
    pub servers: Vec<ServerSpec>,
    pub default_net: NetworkProfile,
    pub client: ClientProfile,
    /// Compress hidden states on the wire (§3.1 dynamic blockwise int8).
    pub compress_activations: bool,
}

/// BLOOM-176B geometry constants used by the Table-3 scenarios.
pub mod bloom176b {
    /// 70 Transformer blocks.
    pub const N_BLOCKS: usize = 70;
    pub const HIDDEN: usize = 14336;
    /// Bytes per block at int8 (~2.44 B params/block x ~1 B).
    pub const BLOCK_BYTES_INT8: u64 = 2_440_000_000;
    /// Bytes per block at 16-bit.
    pub const BLOCK_BYTES_F16: u64 = 4_880_000_000;
    /// 2 * params FLOPs per token per block.
    pub const FLOPS_PER_TOKEN_BLOCK: f64 = 4.88e9;
}

/// Named presets matching the paper's evaluation setups.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SwarmPreset {
    /// 3 physical servers with one A100 each.
    ThreeA100,
    /// 12 virtual servers partitioned from 3 A100s.
    TwelveVirtual,
    /// 14 heterogeneous real servers across Europe + North America.
    FourteenRealWorld,
}

impl SwarmPreset {
    pub fn build(self, net: NetworkProfile, compress: bool) -> SwarmProfile {
        use bloom176b::*;
        let servers = match self {
            SwarmPreset::ThreeA100 => {
                vec![
                    ServerSpec { device: DeviceProfile::A100_80G, net: None, relayed: false };
                    3
                ]
            }
            SwarmPreset::TwelveVirtual => {
                vec![
                    ServerSpec {
                        device: DeviceProfile::VIRTUAL_QUARTER_A100,
                        net: None,
                        relayed: false
                    };
                    12
                ]
            }
            SwarmPreset::FourteenRealWorld => {
                let mut v = Vec::new();
                let devs = [
                    DeviceProfile::RTX_3060,
                    DeviceProfile::RTX_3060,
                    DeviceProfile::RTX_2080TI,
                    DeviceProfile::RTX_2080TI,
                    DeviceProfile::RTX_2080TI,
                    DeviceProfile::RTX_2080TI,
                    DeviceProfile::RTX_3090,
                    DeviceProfile::RTX_3090,
                    DeviceProfile::A4000,
                    DeviceProfile::A4000,
                    DeviceProfile::A5000,
                    DeviceProfile::A5000,
                    DeviceProfile::A5000,
                    DeviceProfile::A5000,
                ];
                for (i, d) in devs.into_iter().enumerate() {
                    // bandwidths 100-1000 Mbit, intercontinental RTTs,
                    // 4 servers behind relays (paper footnote 3)
                    let bw = [1000.0, 100.0, 300.0, 500.0, 100.0, 1000.0, 200.0][i % 7] * MBIT;
                    let rtt = [0.02, 0.09, 0.05, 0.12, 0.07, 0.03, 0.10][i % 7];
                    v.push(ServerSpec {
                        device: d,
                        net: Some(NetworkProfile {
                            bandwidth_bps: bw,
                            rtt_s: rtt,
                            jitter: 0.1,
                            relay_extra_s: if i % 4 == 3 { 0.03 } else { 0.0 },
                        }),
                        relayed: i % 4 == 3,
                    });
                }
                v
            }
        };
        SwarmProfile {
            name: format!("{self:?}"),
            n_blocks: N_BLOCKS,
            bytes_per_block: BLOCK_BYTES_INT8,
            flops_per_token_block: FLOPS_PER_TOKEN_BLOCK,
            hidden: HIDDEN,
            servers,
            default_net: net,
            client: ClientProfile::default(),
            compress_activations: compress,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_hosts_bloom_third_at_int8() {
        // 3 A100s must cover all 70 int8 blocks: >=24 each
        let cap = DeviceProfile::A100_80G.capacity_blocks(bloom176b::BLOCK_BYTES_INT8);
        assert!(cap >= 24, "cap={cap}");
        // ...but NOT at 16-bit (the 44->22 node story)
        let cap16 = DeviceProfile::A100_80G.capacity_blocks(bloom176b::BLOCK_BYTES_F16);
        assert!(cap16 < 24, "cap16={cap16}");
    }

    #[test]
    fn decode_time_memory_bound_scaling() {
        let d = DeviceProfile::A100_80G;
        let t24 = d.decode_time(24, bloom176b::BLOCK_BYTES_INT8, 1);
        let t12 = d.decode_time(12, bloom176b::BLOCK_BYTES_INT8, 1);
        assert!(t24 > 1.9 * t12 - d.overhead_s);
        // ~8 ms/block on the calibrated profile
        let per_block = (t24 - d.overhead_s) / 24.0;
        assert!((0.004..0.012).contains(&per_block), "{per_block}");
    }

    #[test]
    fn forward_time_compute_bound() {
        let d = DeviceProfile::A100_80G;
        let t = d.forward_time(24, 8192, bloom176b::FLOPS_PER_TOKEN_BLOCK);
        // 24 blocks x 8192 tok x 4.88 GFLOP / 100 TFLOPs ~ 9.6 s
        assert!((5.0..20.0).contains(&t), "{t}");
    }

    #[test]
    fn network_message_time() {
        let n = NetworkProfile::MBIT100_100MS;
        // 15 KB hidden state: 50 ms propagation + ~1.2 ms serialization
        let t = n.message_s(15_000);
        assert!((0.050..0.053).contains(&t), "{t}");
        let g = NetworkProfile::GBIT_5MS;
        assert!(g.message_s(15_000) < 0.003);
    }

    #[test]
    fn presets_have_capacity_for_all_blocks() {
        for preset in [
            SwarmPreset::ThreeA100,
            SwarmPreset::TwelveVirtual,
            SwarmPreset::FourteenRealWorld,
        ] {
            let p = preset.build(NetworkProfile::GBIT_5MS, true);
            let total: usize = p
                .servers
                .iter()
                .map(|s| s.device.capacity_blocks(p.bytes_per_block))
                .sum();
            assert!(
                total >= p.n_blocks,
                "{preset:?}: total capacity {total} < {}",
                p.n_blocks
            );
        }
    }

    #[test]
    fn realworld_has_relayed_servers() {
        let p = SwarmPreset::FourteenRealWorld.build(NetworkProfile::GBIT_5MS, true);
        assert_eq!(p.servers.len(), 14);
        assert!(p.servers.iter().filter(|s| s.relayed).count() >= 3);
    }
}
