//! Configuration substrate: JSON parsing ([`json`]), deterministic PRNG
//! ([`rng`]), and the typed device / network / swarm profiles
//! ([`profiles`]) that parameterize every Table-3 scenario.

pub mod json;
pub mod profiles;
pub mod rng;

pub use profiles::{
    ClientProfile, DeviceProfile, NetworkProfile, ServerSpec, SwarmPreset, SwarmProfile,
};
pub use rng::Rng;
