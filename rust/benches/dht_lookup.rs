//! Discovery-plane perf: Kademlia iterative-lookup cost and churn
//! convergence, on the CI perf trajectory as `BENCH_dht.json`.
//!
//! Two layers, mirroring the compute benches:
//!
//! 1. **Simulated** ([`petals::sim::dht`]) at swarm sizes real sockets
//!    would make slow and flaky: metered RPC counts (hops) and virtual
//!    latency at the paper's ~100 ms real-world RTT, plus convergence
//!    time after killing a third of the swarm and republishing.
//! 2. **Real loopback TCP**: a 5-node [`petals::dht::DhtNode`] swarm —
//!    wall-clock iterative `FIND_VALUE` latency through `TcpRpc`, and
//!    wall-clock convergence after a node death + republish.
//!
//! Needs no artifacts, so it runs in every environment that can build
//! the crate. Run: `cargo bench --bench dht_lookup`
//! (`BENCH_OUT` overrides the output path).

use petals::dht::{
    now_ms, BlockDirectory, DhtConfig, DhtNode, NodeId, ServerEntry,
};
use petals::sim::dht::SimDhtNet;
use std::time::{Duration, Instant};

fn main() -> petals::Result<()> {
    println!("kademlia discovery-plane benchmarks\n");

    // ---- simulated swarm: hop counts vs size ----------------------------
    let hop_latency_s = 0.1; // paper's real-world profile: ~100 ms RTT
    println!("simulated swarms @ {:.0} ms/hop:", hop_latency_s * 1000.0);
    println!("| nodes | lookup rpcs (mean) | lookup latency s | churn reconverge s |");
    println!("|---|---|---|---|");
    let mut sim_rows: Vec<(usize, f64, f64, f64)> = Vec::new();
    for &n in &[32usize, 128, 512] {
        let (net, ids) = SimDhtNet::build(n, 42, hop_latency_s);
        // publish 8 block keys from distinct publishers
        let keys: Vec<NodeId> =
            (0..8).map(|i| NodeId::from_name(&format!("bloom/block/{i}"))).collect();
        let ttl_ms = 120_000u64;
        for (i, &key) in keys.iter().enumerate() {
            net.publish(ids[1 + i], &[ids[0]], key, vec![i as u8], ttl_ms);
        }
        // metered lookups from spread-out query nodes
        let (mut rpcs, mut lat) = (0.0f64, 0.0f64);
        let mut samples = 0usize;
        for (i, &key) in keys.iter().enumerate() {
            for q in 0..4 {
                let from = ids[(i * 29 + q * 7 + 11) % n];
                let cost = net.measure_lookup(&[from], key);
                assert!(cost.found >= 1, "sim lookup lost key {i}");
                rpcs += cost.rpcs as f64;
                lat += cost.latency_s;
                samples += 1;
            }
        }
        let (rpcs, lat) = (rpcs / samples as f64, lat / samples as f64);
        // churn: kill a third (sparing publishers + seed), wait out the
        // TTL, republish, and charge the convergence to the clock
        let mut killed = 0usize;
        for i in (9..n).step_by(3) {
            net.kill(ids[i]);
            killed += 1;
        }
        net.advance_s(ttl_ms as f64 / 1000.0 + 1.0);
        let t0 = net.clock_s();
        for (i, &key) in keys.iter().enumerate() {
            net.publish(ids[1 + i], &[ids[0]], key, vec![i as u8], ttl_ms);
            assert!(net.measure_lookup(&[ids[0]], key).found >= 1, "reconverge lost key {i}");
        }
        let reconverge = net.clock_s() - t0;
        println!("| {n} (-{killed}) | {rpcs:.1} | {lat:.2} | {reconverge:.2} |");
        sim_rows.push((n, rpcs, lat, reconverge));
    }

    // ---- real loopback swarm -------------------------------------------
    println!("\nreal loopback TCP swarm (5 DhtNodes, one seed):");
    let cfg = |bootstrap: Vec<String>| DhtConfig {
        bootstrap,
        rpc_timeout: Duration::from_millis(800),
        sweep_every: Duration::from_millis(200),
        ..DhtConfig::default()
    };
    let seed =
        DhtNode::spawn(NodeId::from_name("bench/seed"), "127.0.0.1:0", cfg(vec![]))?;
    let mut nodes = vec![seed];
    for i in 1..5 {
        let n = DhtNode::spawn(
            NodeId::from_name(&format!("bench/n{i}")),
            "127.0.0.1:0",
            cfg(vec![nodes[0].addr()]),
        )?;
        n.bootstrap();
        nodes.push(n);
    }
    let entry = ServerEntry {
        server: nodes[1].id(),
        start: 0,
        end: 4,
        throughput: 1.0,
        free_pages: 8,
        total_pages: 32,
        batch_width: 8,
        prefix_fps: vec![],
        p50_step_us: 0,
        queue_depth: 0,
        sessions_active: 0,
    };
    let churn_ttl_ms = 800u64;
    let publish = |node: &DhtNode, ttl_ms: u64| -> petals::Result<usize> {
        let rpc = node.rpc();
        let mut dir = BlockDirectory::new(&rpc, node.seeds(), "bloom-mini");
        dir.announce_ttl_ms = ttl_ms;
        dir.announce_addressed("127.0.0.1:7001", &entry, now_ms())
    };
    // measurement phase uses a long TTL: 20 iterative lookups at a few
    // ms per dial must not race the record's expiry on a loaded runner
    publish(&nodes[1], 60_000)?;
    let reader = nodes[4].clone();
    let lookup_ok = |node: &DhtNode| {
        let rpc = node.rpc();
        let dir = BlockDirectory::new(&rpc, node.seeds(), "bloom-mini");
        !dir.lookup_addressed(0).is_empty()
    };
    // warm + measured lookups
    assert!(lookup_ok(&reader), "tcp lookup must resolve");
    let n_lookups = 20usize;
    let t0 = Instant::now();
    for _ in 0..n_lookups {
        assert!(lookup_ok(&reader));
    }
    let tcp_lookup_ms = t0.elapsed().as_secs_f64() * 1000.0 / n_lookups as f64;
    println!("  iterative FIND_VALUE: {tcp_lookup_ms:.2} ms/lookup (mean of {n_lookups})");

    // churn: swap in a short-TTL record (same publisher replaces), kill
    // a replica holder, let the TTL expire, republish, and measure wall
    // time until the swarm resolves the entry again
    publish(&nodes[1], churn_ttl_ms)?;
    nodes[2].shutdown();
    std::thread::sleep(Duration::from_millis(churn_ttl_ms + 300));
    assert!(!lookup_ok(&reader), "expired entry must be invisible");
    let t0 = Instant::now();
    publish(&nodes[1], churn_ttl_ms)?;
    let mut tcp_reconverge_ms = -1.0f64;
    while t0.elapsed() < Duration::from_secs(5) {
        if lookup_ok(&reader) {
            tcp_reconverge_ms = t0.elapsed().as_secs_f64() * 1000.0;
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(tcp_reconverge_ms >= 0.0, "swarm never reconverged");
    println!("  churn reconverge (kill + TTL expiry + republish): {tcp_reconverge_ms:.1} ms");
    for n in &nodes {
        n.shutdown();
    }

    // ---- rebalancing vs static assignment under churn -------------------
    // the ISSUE-9 trajectory metric: 256 virtual servers, continuous
    // diurnal churn, identical event schedules in both arms; the
    // rebalancing arm runs the daemon's planner (one elected mover per
    // tick, dwell + min-gain hysteresis), the control keeps join-time
    // spans forever. Deterministic (virtual clock, seeded RNG).
    let churn_w = petals::sim::dht::ChurnWorkload::default();
    let churn = petals::sim::dht::run_rebalance_churn(&churn_w);
    println!(
        "\nrebalancing churn model ({} servers, {} blocks, {:.0}s horizon):",
        churn_w.n_servers, churn_w.n_blocks, churn_w.horizon_s
    );
    println!(
        "  static assignment: {:.1} steps/s (dead {:.1}% of horizon)",
        churn.static_steps_per_s,
        churn.static_dead_frac * 100.0
    );
    println!(
        "  live rebalancing:  {:.1} steps/s (dead {:.1}%, {} moves) — {:.2}x",
        churn.rebalance_steps_per_s,
        churn.rebalance_dead_frac * 100.0,
        churn.moves,
        churn.gain
    );

    // ---- trajectory JSON ------------------------------------------------
    let (big_n, big_rpcs, big_lat, big_reconv) = *sim_rows.last().unwrap();
    // `gates` declares which metrics ci/bench_compare.sh enforces, with
    // per-metric direction and adverse-change threshold. The virtual-
    // latency sim numbers are deterministic (tight bounds); wall-clock
    // TCP numbers ride shared CI runners (loose bounds).
    let json = format!(
        "{{\n  \"sim_hop_latency_ms\": {:.0},\n  \"sim_nodes\": {big_n},\n  \
         \"sim_lookup_rpcs_mean\": {big_rpcs:.2},\n  \"sim_lookup_latency_s\": {big_lat:.3},\n  \
         \"sim_churn_reconverge_s\": {big_reconv:.3},\n  \"tcp_nodes\": {},\n  \
         \"tcp_lookup_ms_mean\": {tcp_lookup_ms:.3},\n  \"tcp_churn_reconverge_ms\": {tcp_reconverge_ms:.1},\n  \
         \"rebalance_churn_servers\": {},\n  \
         \"rebalance_steps_per_s_churn\": {:.2},\n  \
         \"static_steps_per_s_churn\": {:.2},\n  \
         \"rebalance_moves_churn\": {},\n  \
         \"static_vs_rebalance_gain\": {:.3},\n  \
         \"gates\": {{\n    \"sim_lookup_rpcs_mean\": {{\"dir\": \"lower\", \"pct\": 25}},\n    \
         \"sim_lookup_latency_s\": {{\"dir\": \"lower\", \"pct\": 25}},\n    \
         \"tcp_lookup_ms_mean\": {{\"dir\": \"lower\", \"pct\": 200}},\n    \
         \"rebalance_steps_per_s_churn\": {{\"dir\": \"higher\", \"pct\": 25}},\n    \
         \"static_vs_rebalance_gain\": {{\"dir\": \"higher\", \"pct\": 25}}\n  }}\n}}\n",
        hop_latency_s * 1000.0,
        nodes.len(),
        churn_w.n_servers,
        churn.rebalance_steps_per_s,
        churn.static_steps_per_s,
        churn.moves,
        churn.gain,
    );
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_dht.json".into());
    std::fs::write(&out, &json)?;
    println!("\nwrote {out}");
    Ok(())
}
