//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. routing policy: beam search (paper) vs greedy-nearest vs random
//!    valid chain;
//! 2. load balancing: worst-throughput interval selection (paper §3.2)
//!    vs random interval, measured by swarm throughput after joins;
//! 3. rebalancing on/off under churn (coverage recovery);
//! 4. failure recovery: KV replay (paper) vs full session restart,
//!    measured in replayed work.
//!
//! Run: `cargo bench --bench ablations`

use petals::config::profiles::{NetworkProfile, SwarmPreset};
use petals::config::Rng;
use petals::coordinator::balancer::{self, BlockCoverage};
use petals::coordinator::routing::{self, RouteQuery, ServerView};
use petals::dht::NodeId;
use petals::sim::SwarmSim;

fn main() {
    routing_ablation();
    balancing_ablation();
    churn_ablation();
    recovery_ablation();
}

// ---------------------------------------------------------------------------

fn random_views(rng: &mut Rng, n_blocks: usize, n_servers: usize) -> Vec<ServerView> {
    (0..n_servers)
        .map(|i| {
            let start = rng.usize_below(n_blocks);
            let end = (start + 1 + rng.usize_below(n_blocks - start)).min(n_blocks);
            ServerView {
                id: NodeId::from_name(&format!("s{i}")),
                start,
                end,
                latency_s: rng.range_f64(0.002, 0.120),
                bandwidth_bps: rng.range_f64(50e6, 1e9),
                span_compute_s: rng.range_f64(0.02, 0.4),
                queue_depth: rng.usize_below(4) as u32,
                free_ratio: rng.range_f64(0.0, 1.0),
                prefix_fps: vec![],
                p50_step_us: 0,
                measured_step_s: None,
                measured_age_s: 0.0,
            }
        })
        .collect()
}

/// Predicted chain time under the model in routing.rs.
fn chain_cost(servers: &[ServerView], hops: &[routing::ChainHop], q: &RouteQuery) -> f64 {
    let mut cost = 0.0;
    for h in hops {
        let s = servers.iter().find(|s| s.id == h.server).unwrap();
        let frac = (h.end - h.start) as f64 / (s.end - s.start) as f64;
        cost += s.latency_s
            + q.msg_bytes as f64 * 8.0 / s.bandwidth_bps
            + s.span_compute_s * frac
            + s.queue_depth as f64 * q.queue_penalty_s;
    }
    let last = servers
        .iter()
        .find(|s| s.id == hops.last().unwrap().server)
        .unwrap();
    cost + last.latency_s + q.msg_bytes as f64 * 8.0 / last.bandwidth_bps
}

/// Greedy-nearest: at each frontier take the lowest-latency cover.
fn greedy_chain(servers: &[ServerView], q: &RouteQuery) -> Option<Vec<routing::ChainHop>> {
    let mut at = 0;
    let mut hops = Vec::new();
    while at < q.n_blocks {
        let s = servers
            .iter()
            .filter(|s| s.start <= at && s.end > at)
            .min_by(|a, b| a.latency_s.total_cmp(&b.latency_s))?;
        hops.push(routing::ChainHop { server: s.id, start: at, end: s.end.min(q.n_blocks) });
        at = s.end.min(q.n_blocks);
    }
    Some(hops)
}

/// Random valid chain.
fn random_chain(servers: &[ServerView], q: &RouteQuery, rng: &mut Rng) -> Option<Vec<routing::ChainHop>> {
    let mut at = 0;
    let mut hops = Vec::new();
    while at < q.n_blocks {
        let cands: Vec<&ServerView> = servers
            .iter()
            .filter(|s| s.start <= at && s.end > at)
            .collect();
        if cands.is_empty() {
            return None;
        }
        let s = cands[rng.usize_below(cands.len())];
        hops.push(routing::ChainHop { server: s.id, start: at, end: s.end.min(q.n_blocks) });
        at = s.end.min(q.n_blocks);
    }
    Some(hops)
}

fn routing_ablation() {
    println!("ablation 1: routing policy (500 random swarms, 24 blocks)\n");
    let mut rng = Rng::new(0xAB1);
    let q = RouteQuery {
        n_blocks: 24,
        msg_bytes: 60_000,
        ..Default::default()
    };
    let (mut beam_sum, mut greedy_sum, mut random_sum) = (0.0, 0.0, 0.0);
    let mut count = 0;
    for _ in 0..500 {
        let servers = random_views(&mut rng, 24, 12);
        let Some((hops, _)) = routing::find_chain(&servers, &q) else {
            continue;
        };
        let Some(gh) = greedy_chain(&servers, &q) else { continue };
        let Some(rh) = random_chain(&servers, &q, &mut rng) else { continue };
        beam_sum += chain_cost(&servers, &hops, &q);
        greedy_sum += chain_cost(&servers, &gh, &q);
        random_sum += chain_cost(&servers, &rh, &q);
        count += 1;
    }
    println!("| policy | mean predicted step time |");
    println!("|---|---|");
    println!("| beam search (paper) | {:.3} s |", beam_sum / count as f64);
    println!("| greedy nearest | {:.3} s (+{:.0}%)|", greedy_sum / count as f64, (greedy_sum / beam_sum - 1.0) * 100.0);
    println!("| random valid | {:.3} s (+{:.0}%)|", random_sum / count as f64, (random_sum / beam_sum - 1.0) * 100.0);
    println!();
}

fn balancing_ablation() {
    println!("ablation 2: block assignment at join (70 blocks, heterogeneous capacities)\n");
    let mut rng = Rng::new(0xAB2);
    let n_blocks = 70;
    let trials = 300;
    let (mut petals_sum, mut random_sum) = (0.0, 0.0);
    for _ in 0..trials {
        let caps: Vec<usize> = (0..10).map(|_| 8 + rng.usize_below(20)).collect();
        let tputs: Vec<f64> = (0..10).map(|_| rng.range_f64(0.5, 3.0)).collect();
        // petals policy
        let mut cov = BlockCoverage::new(n_blocks);
        for (c, t) in caps.iter().zip(&tputs) {
            let span = balancer::choose_join_span(&cov, *c);
            cov.add_span(span, *t);
        }
        petals_sum += balancer::swarm_throughput(&cov);
        // random policy
        let mut cov = BlockCoverage::new(n_blocks);
        for (c, t) in caps.iter().zip(&tputs) {
            let len = (*c).min(n_blocks);
            let start = rng.usize_below(n_blocks - len + 1);
            cov.add_span(start..start + len, *t);
        }
        random_sum += balancer::swarm_throughput(&cov);
    }
    println!("| join policy | mean swarm throughput |");
    println!("|---|---|");
    println!("| worst-interval (paper §3.2) | {:.3} |", petals_sum / trials as f64);
    println!("| random interval | {:.3} |", random_sum / trials as f64);
    println!();
}

fn churn_ablation() {
    println!("ablation 3: rebalancing under churn (12-virtual swarm, kill 3 servers)\n");
    let mut with_sum = 0.0;
    let mut without_sum = 0.0;
    let mut dead_with = 0;
    let mut dead_without = 0;
    let trials = 20;
    for seed in 0..trials {
        for rebalance in [true, false] {
            let mut sim = SwarmSim::build(
                SwarmPreset::TwelveVirtual.build(NetworkProfile::GBIT_5MS, true),
                seed,
            );
            let mut rng = Rng::new(seed * 7 + 1);
            for _ in 0..3 {
                let alive: Vec<usize> = sim
                    .servers
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| s.alive)
                    .map(|(i, _)| i)
                    .collect();
                sim.kill(alive[rng.usize_below(alive.len())]);
            }
            if rebalance {
                sim.rebalance();
            }
            let tput = sim.total_throughput();
            if rebalance {
                with_sum += tput;
                if tput == 0.0 {
                    dead_with += 1;
                }
            } else {
                without_sum += tput;
                if tput == 0.0 {
                    dead_without += 1;
                }
            }
        }
    }
    println!("| policy | mean throughput after churn | dead swarms |");
    println!("|---|---|---|");
    println!("| rebalancing on (paper) | {:.3} | {dead_with}/{trials} |", with_sum / trials as f64);
    println!("| rebalancing off | {:.3} | {dead_without}/{trials} |", without_sum / trials as f64);
    println!();
}

fn recovery_ablation() {
    println!("ablation 4: failure recovery cost, KV replay vs session restart\n");
    // analytic at BLOOM-176B scale: failing at token t of a generation
    // costs t replayed steps on ONE span (replay) vs t steps on ALL
    // spans + a new prefill (restart)
    println!("| fail at token | replay cost (span-steps) | restart cost |");
    println!("|---|---|---|");
    let chain_len = 9.0;
    for t in [16usize, 64, 256, 1024] {
        let replay = t as f64; // one span re-fed t inputs
        let restart = t as f64 * chain_len + chain_len; // whole chain redone
        println!("| {t} | {replay:.0} | {restart:.0} ({:.1}x) |", restart / replay);
    }
    println!("\n(KV replay touches only the failed span; restart repeats every span — the gap widens with context length)");
}
