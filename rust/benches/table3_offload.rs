//! Table 3 (offloading rows): the parameter-offloading baseline Petals
//! is compared against, plus the headline Petals-vs-offloading ratio.
//!
//! Two parts:
//! 1. the paper's analytic upper bound (PCIe 4.0 x16, zero latency) at
//!    BLOOM-176B scale — all four paper rows;
//! 2. a *real* offloading execution at BLOOM-mini scale (weights
//!    streamed per block through PJRT with a throttled PCIe stand-in)
//!    vs a resident-weight server, validating the model's shape in
//!    running code.
//!
//! Run: `cargo bench --bench table3_offload`

use petals::config::profiles::{NetworkProfile, SwarmPreset};
use petals::model::tensor::Tensor;
use petals::model::{ModelHome, Precision};
use petals::offload::{OffloadExecutor, OffloadModel};
use petals::runtime::Runtime;
use petals::sim::SwarmSim;
use std::sync::Arc;

fn main() -> petals::Result<()> {
    println!("Table 3 (offloading rows, reproduction): BLOOM-176B analytic upper bound\n");
    println!("| Setup | PCIe | inference (steps/s) | forward b=1 (tok/s) | b=64 |");
    println!("|---|---|---|---|---|");
    for (gpus, label) in [(1usize, "1x A100"), (3, "3x A100")] {
        for gbit in [256.0, 128.0] {
            let m = OffloadModel::bloom176b_int8(gbit, gpus);
            println!(
                "| Offloading, {label} | {gbit:.0} Gbit/s | {:.2} | {:.1} | {:.1} |",
                m.decode_steps_per_s(),
                m.forward_tokens_per_s(1, 128),
                m.forward_tokens_per_s(64, 128),
            );
        }
    }
    println!("\npaper rows: 1x: 0.18/0.09 steps/s; 3x: 0.09/0.05 steps/s");

    // headline ratio
    let mut sim = SwarmSim::build(SwarmPreset::ThreeA100.build(NetworkProfile::GBIT_5MS, true), 0);
    let petals = sim.run_inference(128, 32, 1).unwrap().steps_per_s;
    let offload = OffloadModel::bloom176b_int8(256.0, 1).decode_steps_per_s();
    println!(
        "\nheadline: Petals {petals:.2} steps/s vs best offloading {offload:.2} steps/s = {:.1}x",
        petals / offload
    );

    // ---- part 2: real mini-scale offloading vs resident ----------------
    println!("\nreal BLOOM-mini execution (CPU PJRT): offload-streamed vs resident weights");
    let home = ModelHome::open("artifacts")?;
    let g = home.geometry().clone();
    let rt = Arc::new(Runtime::load_filtered(&home, |n| n == "block_prefill_b1_s128")?);

    let mut vals = vec![0f32; 128 * g.hidden];
    let mut rng = petals::config::Rng::new(0);
    for v in vals.iter_mut() {
        *v = (rng.f64() as f32 - 0.5) * 0.5;
    }
    let h = Tensor::from_f32(&[1, 128, g.hidden], &vals);

    let resident = petals::server::ServerNode::start(
        "resident", &home, rt.clone(), 0..g.n_layers, Precision::F16, false,
    )?;
    let t0 = std::time::Instant::now();
    let n_sweeps = 5;
    for _ in 0..n_sweeps {
        resident.forward(&h)?;
    }
    let resident_s = t0.elapsed().as_secs_f64() / n_sweeps as f64;

    let mut off = OffloadExecutor::new(&home, rt, Precision::F16)?;
    // throttle the weight stream to a "PCIe" that moves the mini model
    // in ~4x the resident forward time (mirrors 176B-scale ratios where
    // transfer dominates)
    let model_bytes: f64 = (g.block_bytes_f16 * g.n_layers as u64) as f64;
    off.pcie_bytes_per_s = Some(model_bytes / (resident_s * 4.0));
    let mut off_s = 0.0;
    for _ in 0..n_sweeps {
        let (_, dt) = off.forward_sweep(&h)?;
        off_s += dt.as_secs_f64();
    }
    off_s /= n_sweeps as f64;

    println!("  resident forward sweep: {resident_s:.3} s");
    println!("  offloaded forward sweep: {off_s:.3} s");
    println!("  slowdown from offloading: {:.1}x (transfer-dominated, as at 176B scale)", off_s / resident_s);
    Ok(())
}
