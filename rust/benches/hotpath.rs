//! Hot-path microbenchmarks — the §Perf instrument (before/after in
//! EXPERIMENTS.md).
//!
//! Breaks one decode step into its L3 cost components:
//!   - tensor -> literal conversion (per-call marshalling)
//!   - artifact execution per block (f16 and int8)
//!   - KV-cache literal refeed (the optimization: no host repack)
//!   - comm codec (quantize+encode / decode+dequantize)
//!   - routing decision + DHT lookup (control plane)
//!
//! Run: `cargo bench --bench hotpath`

use petals::config::Rng;
use petals::model::tensor::{DType, Tensor};
use petals::model::{ModelHome, Precision};
use petals::quant;
use petals::runtime::Runtime;
use petals::server::ServerNode;
use std::sync::Arc;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // warmup
    f();
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("  {name:44} {:>10.1} us", per * 1e6);
    per
}

fn main() -> petals::Result<()> {
    let home = ModelHome::open("artifacts")?;
    let g = home.geometry().clone();
    let rt = Arc::new(Runtime::load_filtered(&home, |n| {
        n.contains("_b1_") || n.ends_with("_b1")
    })?);

    println!("== L3 hot path breakdown (BLOOM-mini, CPU PJRT) ==\n");

    // --- marshalling -------------------------------------------------------
    let mut rng = Rng::new(0);
    let vals: Vec<f32> = (0..g.hidden).map(|_| rng.f64() as f32).collect();
    let h = Tensor::from_f32(&[1, 1, g.hidden], &vals);
    println!("marshalling:");
    bench("tensor->literal [1,1,H]", 1000, || {
        let _ = h.to_literal().unwrap();
    });
    let kv = Tensor::zeros(&[1, g.n_heads, g.max_seq, g.head_dim], DType::F32);
    bench("tensor->literal KV [1,Hh,C,D] (4 MB)", 100, || {
        let _ = kv.to_literal().unwrap();
    });

    // --- single-block execution --------------------------------------------
    println!("\nblock execution (per block, per step):");
    let f16 = ServerNode::start("f16", &home, rt.clone(), 0..1, Precision::F16, false)?;
    f16.open_session(1, 1, 0)?;
    let wide = Tensor::zeros(&[1, 128, g.hidden], DType::F32);
    f16.prefill(1, &wide)?;
    let mut step = 8usize;
    bench("f16 decode step (1 block incl. caches)", 50, || {
        f16.step(1, step, &h).unwrap();
        step += 1;
        if step > 200 {
            step = 8;
        }
    });
    let int8 = ServerNode::start("int8", &home, rt.clone(), 0..1, Precision::Int8, false)?;
    int8.open_session(1, 1, 0)?;
    int8.prefill(1, &wide)?;
    let mut step8 = 8usize;
    bench("int8 decode step (1 block incl. caches)", 20, || {
        int8.step(1, step8, &h).unwrap();
        step8 += 1;
        if step8 > 200 {
            step8 = 8;
        }
    });
    bench("f16 prefill 128 tok (1 block)", 20, || {
        f16.prefill(1, &wide).unwrap();
    });

    // --- comm codec ---------------------------------------------------------
    println!("\ncomm codec (hidden state, 1 token):");
    bench("quantize+encode", 5000, || {
        let q = quant::quantize(&h);
        let _ = quant::encode(&q);
    });
    let enc = quant::encode(&quant::quantize(&h));
    bench("decode+dequantize", 5000, || {
        let q = quant::decode(&enc).unwrap();
        let _ = quant::dequantize(&q);
    });

    // --- control plane -------------------------------------------------------
    println!("\ncontrol plane:");
    use petals::coordinator::routing::{find_chain, RouteQuery, ServerView};
    use petals::dht::NodeId;
    let views: Vec<ServerView> = (0..14)
        .map(|i| {
            let start = (i * 5) % 70;
            ServerView {
                id: NodeId::from_name(&format!("s{i}")),
                start,
                end: (start + 24).min(70),
                latency_s: 0.01 + i as f64 * 0.002,
                bandwidth_bps: 1e8,
                span_compute_s: 0.2,
                queue_depth: 0,
                free_ratio: 1.0,
                prefix_fps: vec![],
                p50_step_us: 0,
                measured_step_s: None,
                measured_age_s: 0.0,
            }
        })
        .collect();
    let q = RouteQuery {
        n_blocks: 70,
        msg_bytes: 15_000,
        ..Default::default()
    };
    bench("beam-search route (70 blocks, 14 servers)", 2000, || {
        let _ = find_chain(&views, &q);
    });

    // DHT iterative lookup over an in-memory 100-node net
    println!("\n(end of hot-path breakdown)");
    Ok(())
}
