//! §3.3 multi-client experiment + the continuous-batching lever.
//!
//! Paper baseline: "For 12 servers with 100 Mbit/s bandwidth and 100 ms
//! latency, if 8 clients run inference concurrently, each of them gets
//! ≈20% slowdown compared to the case when it runs inference alone."
//!
//! Part 1: the simulator at BLOOM-176B scale — client-count sweep with
//! server-side continuous batching OFF (the seed's serialized servers)
//! and ON (requests arriving at a busy server join the in-flight batch),
//! against the sequential per-session baseline.
//! Part 2: real concurrent clients (threads) against a real local swarm
//! at BLOOM-mini scale — sessions flow through the paged KV pool and the
//! group-commit step scheduler; contention through actual PJRT
//! serialization.
//!
//! Run: `cargo bench --bench multiclient`

use petals::config::profiles::{NetworkProfile, SwarmPreset};
use petals::coordinator::client::{LocalHead, Sampler, SwarmGenerator};
use petals::coordinator::routing::RouteQuery;
use petals::coordinator::session::SessionConfig;
use petals::model::{ModelHome, Precision, Weights};
use petals::runtime::Runtime;
use petals::server::local::spawn_even_swarm;
use petals::sim::SwarmSim;
use std::sync::Arc;

fn sim_swarm(batched: bool) -> SwarmSim {
    let mut s =
        SwarmSim::build(SwarmPreset::TwelveVirtual.build(NetworkProfile::MBIT100_100MS, true), 0);
    s.continuous_batching = batched;
    s
}

fn main() -> petals::Result<()> {
    println!("multi-client slowdown & continuous batching (§3.3 + follow-up)\n");
    println!("simulated 12-virtual swarm @ 100 Mbit/s, 100 ms RTT (BLOOM-176B):");
    let solo = sim_swarm(false).run_inference(128, 32, 1).unwrap().steps_per_s;
    println!("sequential per-session baseline: {solo:.2} steps/s aggregate (one session at a time)\n");
    println!("| clients | per-client (serial) | per-client (batched) | aggregate (serial) | aggregate (batched) |");
    println!("|---|---|---|---|---|");
    for n in [1usize, 2, 4, 8, 16] {
        let serial = sim_swarm(false).run_inference_concurrent(n, 128, 32).unwrap();
        let batched = sim_swarm(true).run_inference_concurrent(n, 128, 32).unwrap();
        let mean = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
        let agg = |v: &Vec<f64>| v.iter().sum::<f64>();
        println!(
            "| {n} | {:.2} ({:+.0}%) | {:.2} ({:+.0}%) | {:.2} | {:.2} |",
            mean(&serial),
            (mean(&serial) / solo - 1.0) * 100.0,
            mean(&batched),
            (mean(&batched) / solo - 1.0) * 100.0,
            agg(&serial),
            agg(&batched),
        );
        if n >= 4 {
            assert!(
                agg(&batched) > solo,
                "{n} batched clients must beat the sequential baseline"
            );
        }
    }
    println!("(paper: 8 clients -> ~20% per-client slowdown without batching)\n");

    // ---- real concurrent clients on BLOOM-mini --------------------------
    println!("real concurrent clients, BLOOM-mini local swarm (CPU PJRT),");
    println!("sessions served from the paged KV pool through the step scheduler:");
    let home = ModelHome::open("artifacts")?;
    let g = home.geometry().clone();
    let rt = Arc::new(Runtime::load_filtered(&home, |n| {
        n.contains("_b1_") || n.ends_with("_b1")
    })?);
    let cluster = Arc::new(spawn_even_swarm(&home, rt.clone(), 2, Precision::F16)?);
    let weights = Weights::load(&home, Precision::F16)?;
    let head = Arc::new(LocalHead::new(&home, rt, &weights)?);
    let cfg = SessionConfig {
        n_blocks: g.n_layers,
        batch: 1,
        prefill_width: 128,
        prefix_len: 8,
        max_new: 8,
        route: RouteQuery {
            n_blocks: g.n_layers,
            msg_bytes: (g.hidden * 4) as u64,
            beam_width: 8,
            queue_penalty_s: 0.05,
            pool_penalty_s: 0.05,
        },
        max_recoveries: 2,
    };

    // sequential per-session baseline: 4 sessions, one after another
    let run_one = |c: usize, session_base: u64| {
        let generator = SwarmGenerator {
            swarm: cluster.as_ref(),
            head: head.as_ref(),
            cfg: cfg.clone(),
            sampler: Sampler::Greedy,
        };
        let prefix: Vec<i32> = (0..8).map(|i| (c * 31 + i) as i32 % 100).collect();
        let out = generator.generate(&[prefix], 8, session_base + c as u64).unwrap();
        out.steps
    };
    let t0 = std::time::Instant::now();
    let mut seq_tokens = 0usize;
    for c in 0..4 {
        seq_tokens += run_one(c, 100);
    }
    let seq_aggregate = seq_tokens as f64 / t0.elapsed().as_secs_f64();
    println!("sequential baseline (4 sessions back-to-back): {seq_aggregate:.2} tokens/s aggregate\n");

    println!("| clients | tokens/s per client | aggregate tokens/s |");
    println!("|---|---|---|");
    for n in [1usize, 2, 4] {
        let mut handles = Vec::new();
        for c in 0..n {
            let cluster = cluster.clone();
            let head = head.clone();
            let cfg = cfg.clone();
            handles.push(std::thread::spawn(move || {
                let generator = SwarmGenerator {
                    swarm: cluster.as_ref(),
                    head: head.as_ref(),
                    cfg,
                    sampler: Sampler::Greedy,
                };
                let prefix: Vec<i32> = (0..8).map(|i| (c * 31 + i) as i32 % 100).collect();
                let out = generator.generate(&[prefix], 8, 500 + c as u64).unwrap();
                out.steps as f64 / out.wall.as_secs_f64()
            }));
        }
        let rates: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let mean: f64 = rates.iter().sum::<f64>() / rates.len() as f64;
        let aggregate: f64 = rates.iter().sum();
        println!("| {n} | {mean:.2} | {aggregate:.2} |");
    }
    // fused-batch diagnostics from the servers themselves
    for id in cluster.ids() {
        let node = cluster.node(id).unwrap();
        let (free, total) = node.pool_stats();
        println!("server {}: {} (pool {free}/{total} free)", id.short(), node.metrics.report());
    }
    println!("(CPU PJRT serializes executions; fused batches need b>1 decode artifacts — the");
    println!(" scheduler falls back to per-session execution when only b1 entries are compiled)");
    Ok(())
}
