//! §3.3 multi-client experiment + the continuous-batching lever.
//!
//! Paper baseline: "For 12 servers with 100 Mbit/s bandwidth and 100 ms
//! latency, if 8 clients run inference concurrently, each of them gets
//! ≈20% slowdown compared to the case when it runs inference alone."
//!
//! Part 0: the RAGGED mixed-length sweep (pure sim, no artifacts) — the
//! pre-ragged same-depth join gate vs the per-row-cache_len scheduler
//! over one arrival trace; emits `BENCH_ragged.json` (occupancy,
//! aggregate steps/s, p50 TTFT + its gate declarations) so CI tracks
//! and enforces the ragged trajectory even on artifact-less runners
//! (`BENCH_RAGGED_OUT` overrides the path).
//! Part 1: the simulator at BLOOM-176B scale — client-count sweep with
//! server-side continuous batching OFF (the seed's serialized servers)
//! and ON (requests arriving at a busy server join the in-flight batch),
//! against the sequential per-session baseline.
//! Part 2: real concurrent clients (threads) against a real local swarm
//! at BLOOM-mini scale — sessions flow through the paged KV pool and the
//! group-commit step scheduler; contention through actual PJRT
//! serialization.
//! Part 3: the shared-prefix scenario — N clients sending one system
//! prompt. Simulated at BLOOM-176B scale (time-to-first-token with the
//! prefix cache on/off) and real at BLOOM-mini scale (pool pages per
//! session drop to the marginal suffix cost; prefills after the first
//! are answered from the cache). Emits `BENCH_prefix_cache.json`
//! (override the path with `BENCH_OUT`) so CI tracks the perf
//! trajectory.
//!
//! Run: `cargo bench --bench multiclient`

use petals::config::profiles::{NetworkProfile, SwarmPreset};
use petals::coordinator::client::{LocalHead, Sampler, SwarmGenerator};
use petals::coordinator::routing::RouteQuery;
use petals::coordinator::session::{InferenceSession, PromptShape, SessionConfig};
use petals::model::tensor::Tensor;
use petals::model::{ModelHome, Precision, Weights};
use petals::runtime::Runtime;
use petals::server::local::spawn_even_swarm;
use petals::server::{KvPool, KvPoolConfig, ServerNode, SessionSnapshot};
use petals::sim::faults::MockChain;
use petals::sim::SwarmSim;
use std::sync::Arc;

fn sim_swarm(batched: bool) -> SwarmSim {
    let mut s =
        SwarmSim::build(SwarmPreset::TwelveVirtual.build(NetworkProfile::MBIT100_100MS, true), 0);
    s.continuous_batching = batched;
    s
}

/// Session-durability micro-bench (pure Rust, no artifacts): the two
/// wall-clock costs the migration/resume machinery adds to the serving
/// path. Returns `(migration_ms, resume_ttft_ms)`:
///
/// - `migration_ms` — mean time to move one session's KV state through
///   the full live-migration payload path: `snapshot_session` → wire
///   `encode` → `decode` → `restore_session` onto a fresh pool. This is
///   the donor+target CPU cost per migrated session (network excluded).
/// - `resume_ttft_ms` — mean time from `InferenceSession::restore` of a
///   client-side snapshot to the first post-resume step output, i.e.
///   how long a crashed client waits for its first token after
///   re-attaching (replay included, transport is the in-process mock).
///
/// Both are reported in `BENCH_ragged.json` as tracked metrics but NOT
/// gated: sub-millisecond wall timings are runner-noisy, and the
/// deterministic sim numbers remain the regression gates.
fn bench_session_durability() -> petals::Result<(f64, f64)> {
    println!("session durability: migration payload + client resume (pure Rust):");

    // ---- migration_ms: KvPool snapshot/encode/decode/restore ----------
    // BLOOM-mini-ish session: 16 heads x 64 dims, 24 blocks, 256 tokens.
    let cfg = KvPoolConfig { n_heads: 16, head_dim: 64, page_tokens: 16, capacity_pages: 1024 };
    let (n_blocks, tokens) = (24usize, 256usize);
    let mut pool = KvPool::new(cfg.clone());
    pool.open_session(1, 1, n_blocks, tokens)?;
    pool.prepare_write(1, tokens - 1)?;
    let src: Vec<f32> =
        (0..cfg.n_heads * tokens * cfg.head_dim).map(|i| (i % 251) as f32 * 0.01).collect();
    for block in 0..n_blocks {
        for kv in 0..2 {
            pool.write_prefill(1, block, kv, &src, tokens)?;
        }
    }
    pool.commit_len(1, tokens);
    let iters = 5;
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        let snap = pool.snapshot_session(1)?;
        let bytes = snap.encode();
        let back = SessionSnapshot::decode(&bytes)?;
        let mut fresh = KvPool::new(cfg.clone());
        fresh.restore_session(&back)?;
        assert!(fresh.has_session(1));
    }
    let migration_ms = t0.elapsed().as_secs_f64() * 1000.0 / iters as f64;
    let payload_mb = (pool.snapshot_session(1)?.encode().len() as f64) / (1024.0 * 1024.0);
    println!("  migration round-trip: {migration_ms:.2} ms/session ({payload_mb:.1} MiB payload)");

    // ---- resume_ttft_ms: client snapshot -> restore -> first step -----
    let scfg = || SessionConfig {
        n_blocks: 8,
        max_new: 64,
        route: RouteQuery { n_blocks: 8, msg_bytes: 64, ..Default::default() },
        max_recoveries: 2,
        prefix_tokens: vec![],
    };
    let chain = MockChain::new(&[("bench-a", 0, 4), ("bench-b", 4, 8)]);
    let shape = PromptShape { batch: 1, prefix_len: 2, prefill_width: 4 };
    let mut s = InferenceSession::open(&chain, scfg(), shape, 900)?;
    s.prefill(Tensor::from_f32(&[1, 4, 4], &[0.5; 16]))?;
    let step_in = |i: usize| Tensor::from_f32(&[1, 1, 4], &[i as f32 * 0.25; 4]);
    for i in 0..4 {
        s.step(step_in(i))?;
    }
    let state = s.snapshot();
    drop(s); // the "crashed" client never closes
    let iters = 20;
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        let mut r = InferenceSession::restore(&chain, scfg(), state.clone())?;
        r.step(step_in(4))?;
    }
    let resume_ttft_ms = t0.elapsed().as_secs_f64() * 1000.0 / iters as f64;
    println!("  resume-to-first-token: {resume_ttft_ms:.2} ms (replay of 1 prefill + 4 steps)\n");
    Ok((migration_ms, resume_ttft_ms))
}

/// Observability smoke: stand up the Prometheus exporter on a loopback
/// port, scrape it once over real TCP, and count the exposed series.
/// Returns `(scrape_ok, metrics_series)` — recorded in
/// `BENCH_ragged.json` as tracked (NOT gated) fields so CI notices if
/// the exposition endpoint ever stops parsing, without making a
/// wall-clock-noisy network check a merge blocker.
fn bench_metrics_scrape() -> (bool, usize) {
    use petals::metrics::NodeMetrics;
    use petals::server::service::serve_metrics_with;
    let m = Arc::new(NodeMetrics::new());
    m.requests.inc();
    m.step_latency.record_us(1500);
    let render = {
        let m = m.clone();
        move || m.prometheus()
    };
    let handle = match serve_metrics_with(render, "bench-metrics", "127.0.0.1:0") {
        Ok(h) => h,
        Err(_) => return (false, 0),
    };
    let r = petals::api::http_get(&handle.addr, "/metrics");
    handle.shutdown();
    match r {
        Ok((200, ct, body)) if ct.starts_with("text/plain") => {
            let series =
                body.lines().filter(|l| !l.is_empty() && !l.starts_with('#')).count();
            println!(
                "metrics self-scrape: ok ({series} series from one counter bump + one \
                 histogram sample)\n"
            );
            (true, series)
        }
        _ => {
            println!("metrics self-scrape: FAILED (tracked in BENCH_ragged.json)\n");
            (false, 0)
        }
    }
}

/// Speculative-decoding sweep (pure sim — no artifacts): k=6 n-gram
/// drafts shipped per `ProposeVerify` round (wire v8) at a range of
/// per-draft hit rates on the high-latency 12-virtual swarm, against
/// the sequential decode baseline from the same swarm. The PR's
/// acceptance floor — ≥2× committed tokens/s at hit rate 0.6 — is
/// asserted here, and the gate point rides into `BENCH_ragged.json`
/// as gated trajectory metrics.
///
/// Returns `(tokens_per_round, accept_rate, tokens_per_s_speculative,
/// tokens_per_s_sequential)` at the hit-0.6 gate point.
fn bench_spec_sweep() -> (f64, f64, f64, f64) {
    println!("speculative decoding: drafts over ProposeVerify (sim, BLOOM-176B, k=6):");
    let base = sim_swarm(false).run_inference(128, 64, 1).unwrap().steps_per_s;
    println!("sequential decode baseline: {base:.2} tokens/s");
    println!("| per-draft hit rate | tokens/round | accept rate | tokens/s | vs sequential |");
    println!("|---|---|---|---|---|");
    let mut gate = (0.0f64, 0.0f64, 0.0f64);
    for hit in [0.0, 0.5, 0.6, 0.7, 0.9] {
        let mut s = sim_swarm(false);
        let r = s.run_inference_speculative(128, 1024, 6, hit).unwrap();
        println!(
            "| {hit:.1} | {:.2} | {:.3} | {:.2} | {:.2}x |",
            r.tokens_per_round,
            r.accept_rate,
            r.tokens_per_s,
            r.tokens_per_s / base
        );
        if (hit - 0.6).abs() < 1e-9 {
            gate = (r.tokens_per_round, r.accept_rate, r.tokens_per_s);
        }
    }
    let (tpr, acc, tps) = gate;
    assert!(
        tps >= 2.0 * base,
        "spec-decode floor: {tps:.2} tokens/s at hit 0.6 must be >=2x the sequential {base:.2}"
    );
    println!("(gate point: hit 0.6 -> {tpr:.2} tokens/round, {:.2}x sequential)\n", tps / base);
    (tpr, acc, tps, base)
}

/// Adversarial-tenant fairness (pure sim): one storming tenant dumps 48
/// single-row decode sessions at t≈0; 8 well-behaved tenants trickle in
/// behind it. Reports the p99 TTFT of the well-behaved cohort under the
/// gateway's weighted-fair queueing relative to the no-storm baseline —
/// the gated `fair_p99_ttft_ratio` in `BENCH_ragged.json` (lower is
/// better; the tenancy test suite enforces the hard 2x acceptance
/// bound). The FIFO column is printed for contrast but not gated: it is
/// unbounded in the storm's backlog size by construction.
fn bench_fairness() -> f64 {
    println!("adversarial-tenant fairness: 1 storming tenant (48 rows) vs 8 well-behaved (sim):");
    let fair = |storm: usize, wfq: bool| {
        let mut s = sim_swarm(true);
        s.max_batch_width = 16;
        s.run_inference_fair_mix(8, storm, 8, wfq).unwrap()
    };
    let base = fair(0, true);
    let wfq = fair(48, true);
    let fifo = fair(48, false);
    let ratio = wfq.p99_ttft_s / base.p99_ttft_s;
    println!("| scenario | p99 TTFT (well-behaved) | vs baseline |");
    println!("|---|---|---|");
    println!("| no storm (baseline) | {:.3}s | 1.00x |", base.p99_ttft_s);
    println!("| storm, WFQ | {:.3}s | {ratio:.2}x |", wfq.p99_ttft_s);
    println!(
        "| storm, FIFO | {:.3}s | {:.2}x |",
        fifo.p99_ttft_s,
        fifo.p99_ttft_s / base.p99_ttft_s
    );
    assert!(
        ratio <= 2.0,
        "WFQ must hold well-behaved p99 TTFT within 2x of the no-storm baseline (got {ratio:.2}x)"
    );
    println!("(gate point: fair_p99_ttft_ratio = {ratio:.3}, storm still got {} row-steps)\n", wfq.storm_row_steps);
    ratio
}

/// Mixed-length ragged sweep (pure sim — no artifacts, no toolchain
/// beyond cargo): the pre-ragged same-depth join gate vs the ragged
/// scheduler over one arrival trace of mixed prompt lengths. Emits
/// `BENCH_ragged.json` with its gate declarations so
/// `ci/bench_compare.sh` can enforce the trajectory on main. The two
/// durability timings, the metrics scrape, and the speculative-decode
/// gate point ride along as tracked fields (the spec tokens/s and
/// speedup are gated).
fn bench_ragged_mix(
    migration_ms: f64,
    resume_ttft_ms: f64,
    scrape_ok: bool,
    metrics_series: usize,
    spec: (f64, f64, f64, f64),
    fair_p99_ttft_ratio: f64,
) -> petals::Result<()> {
    println!("ragged continuous batching: mixed-length arrival mix (sim, BLOOM-176B):");
    let lens: Vec<usize> = vec![32, 48, 64, 96, 128, 160, 192, 224];
    let run = |gate: bool| {
        let mut s = sim_swarm(true);
        s.uniform_depth_gate = gate;
        s.run_inference_ragged_mix(&lens, 32).unwrap()
    };
    let old = run(true);
    let new = run(false);
    println!("| scheduler | occupancy | aggregate steps/s | p50 TTFT |");
    println!("|---|---|---|---|");
    println!(
        "| uniform-depth gate (pre-ragged) | {:.3} | {:.2} | {:.2}s |",
        old.occupancy, old.aggregate_steps_per_s, old.p50_ttft_s
    );
    println!(
        "| ragged (per-row cache_len) | {:.3} | {:.2} | {:.2}s |",
        new.occupancy, new.aggregate_steps_per_s, new.p50_ttft_s
    );
    assert!(
        new.aggregate_steps_per_s > old.aggregate_steps_per_s,
        "ragged batching must lift aggregate throughput on a mixed-length mix"
    );
    let (spec_tpr, spec_accept, spec_tps, seq_tps) = spec;
    let json = format!(
        "{{\n  \"clients\": {},\n  \"mix_lens\": [{}],\n  \"occupancy\": {:.4},\n  \
         \"aggregate_steps_per_s\": {:.3},\n  \"p50_ttft_s\": {:.3},\n  \
         \"uniform_gate_occupancy\": {:.4},\n  \"uniform_gate_aggregate_steps_per_s\": {:.3},\n  \
         \"migration_ms\": {migration_ms:.3},\n  \"resume_ttft_ms\": {resume_ttft_ms:.3},\n  \
         \"scrape_ok\": {scrape_ok},\n  \"metrics_series\": {metrics_series},\n  \
         \"tokens_per_round\": {spec_tpr:.3},\n  \"accept_rate\": {spec_accept:.4},\n  \
         \"tokens_per_s_speculative\": {spec_tps:.3},\n  \
         \"tokens_per_s_sequential\": {seq_tps:.3},\n  \
         \"spec_speedup\": {:.3},\n  \
         \"fair_p99_ttft_ratio\": {fair_p99_ttft_ratio:.3},\n  \
         \"gates\": {{\n    \"occupancy\": {{\"dir\": \"higher\", \"pct\": 15}},\n    \
         \"aggregate_steps_per_s\": {{\"dir\": \"higher\", \"pct\": 10}},\n    \
         \"p50_ttft_s\": {{\"dir\": \"lower\", \"pct\": 20}},\n    \
         \"tokens_per_s_speculative\": {{\"dir\": \"higher\", \"pct\": 10}},\n    \
         \"spec_speedup\": {{\"dir\": \"higher\", \"pct\": 10}},\n    \
         \"fair_p99_ttft_ratio\": {{\"dir\": \"lower\", \"pct\": 25}}\n  }}\n}}\n",
        lens.len(),
        lens.iter().map(|l| l.to_string()).collect::<Vec<_>>().join(", "),
        new.occupancy,
        new.aggregate_steps_per_s,
        new.p50_ttft_s,
        old.occupancy,
        old.aggregate_steps_per_s,
        spec_tps / seq_tps,
    );
    let out =
        std::env::var("BENCH_RAGGED_OUT").unwrap_or_else(|_| "BENCH_ragged.json".into());
    std::fs::write(&out, &json)?;
    println!("wrote {out}\n");
    Ok(())
}

fn main() -> petals::Result<()> {
    println!("multi-client slowdown & continuous batching (§3.3 + follow-up)\n");
    // the durability timings and ragged sweep run FIRST and need no
    // artifacts: CI always gets a fresh BENCH_ragged.json even on
    // artifact-less runners
    let (migration_ms, resume_ttft_ms) = bench_session_durability()?;
    let (scrape_ok, metrics_series) = bench_metrics_scrape();
    let spec = bench_spec_sweep();
    let fair_ratio = bench_fairness();
    bench_ragged_mix(migration_ms, resume_ttft_ms, scrape_ok, metrics_series, spec, fair_ratio)?;
    println!("simulated 12-virtual swarm @ 100 Mbit/s, 100 ms RTT (BLOOM-176B):");
    let solo = sim_swarm(false).run_inference(128, 32, 1).unwrap().steps_per_s;
    println!("sequential per-session baseline: {solo:.2} steps/s aggregate (one session at a time)\n");
    println!("| clients | per-client (serial) | per-client (batched) | aggregate (serial) | aggregate (batched) |");
    println!("|---|---|---|---|---|");
    for n in [1usize, 2, 4, 8, 16] {
        let serial = sim_swarm(false).run_inference_concurrent(n, 128, 32).unwrap();
        let batched = sim_swarm(true).run_inference_concurrent(n, 128, 32).unwrap();
        let mean = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
        let agg = |v: &Vec<f64>| v.iter().sum::<f64>();
        println!(
            "| {n} | {:.2} ({:+.0}%) | {:.2} ({:+.0}%) | {:.2} | {:.2} |",
            mean(&serial),
            (mean(&serial) / solo - 1.0) * 100.0,
            mean(&batched),
            (mean(&batched) / solo - 1.0) * 100.0,
            agg(&serial),
            agg(&batched),
        );
        if n >= 4 {
            assert!(
                agg(&batched) > solo,
                "{n} batched clients must beat the sequential baseline"
            );
        }
    }
    println!("(paper: 8 clients -> ~20% per-client slowdown without batching)\n");

    // ---- real concurrent clients on BLOOM-mini --------------------------
    // everything below executes AOT artifacts; without them the sim
    // numbers above (including BENCH_ragged.json) are still complete
    let home = match ModelHome::open("artifacts") {
        Ok(h) => h,
        Err(_) => {
            println!("\nSKIP: no AOT artifacts (run 'make artifacts') — the real-swarm");
            println!("      sections and BENCH_prefix_cache.json are skipped; the sim");
            println!("      sweep and BENCH_ragged.json above are complete.");
            return Ok(());
        }
    };
    println!("real concurrent clients, BLOOM-mini local swarm (CPU PJRT),");
    println!("sessions served from the paged KV pool through the step scheduler:");
    let g = home.geometry().clone();
    let rt = Arc::new(Runtime::load_filtered(&home, |n| {
        n.contains("_b1_") || n.ends_with("_b1")
    })?);
    let cluster = Arc::new(spawn_even_swarm(&home, rt.clone(), 2, Precision::F16)?);
    let weights = Weights::load(&home, Precision::F16)?;
    let head = Arc::new(LocalHead::new(&home, rt, &weights)?);
    let cfg = SessionConfig {
        n_blocks: g.n_layers,
        max_new: 8,
        route: RouteQuery {
            n_blocks: g.n_layers,
            msg_bytes: (g.hidden * 4) as u64,
            ..Default::default()
        },
        max_recoveries: 2,
        prefix_tokens: vec![],
    };

    // sequential per-session baseline: 4 sessions, one after another
    let run_one = |c: usize, session_base: u64| {
        let generator = SwarmGenerator {
            swarm: cluster.as_ref(),
            head: head.as_ref(),
            cfg: cfg.clone(),
            sampler: Sampler::Greedy,
        };
        let prefix: Vec<i32> = (0..8).map(|i| (c * 31 + i) as i32 % 100).collect();
        let out = generator.generate(&[prefix], 8, session_base + c as u64).unwrap();
        out.steps
    };
    let t0 = std::time::Instant::now();
    let mut seq_tokens = 0usize;
    for c in 0..4 {
        seq_tokens += run_one(c, 100);
    }
    let seq_aggregate = seq_tokens as f64 / t0.elapsed().as_secs_f64();
    println!("sequential baseline (4 sessions back-to-back): {seq_aggregate:.2} tokens/s aggregate\n");

    println!("| clients | tokens/s per client | aggregate tokens/s |");
    println!("|---|---|---|");
    for n in [1usize, 2, 4] {
        let mut handles = Vec::new();
        for c in 0..n {
            let cluster = cluster.clone();
            let head = head.clone();
            let cfg = cfg.clone();
            handles.push(std::thread::spawn(move || {
                let generator = SwarmGenerator {
                    swarm: cluster.as_ref(),
                    head: head.as_ref(),
                    cfg,
                    sampler: Sampler::Greedy,
                };
                let prefix: Vec<i32> = (0..8).map(|i| (c * 31 + i) as i32 % 100).collect();
                let out = generator.generate(&[prefix], 8, 500 + c as u64).unwrap();
                out.steps as f64 / out.wall.as_secs_f64()
            }));
        }
        let rates: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let mean: f64 = rates.iter().sum::<f64>() / rates.len() as f64;
        let aggregate: f64 = rates.iter().sum();
        println!("| {n} | {mean:.2} | {aggregate:.2} |");
    }
    // fused-batch diagnostics from the servers themselves
    for id in cluster.ids() {
        let node = cluster.node(id).unwrap();
        let (free, total) = node.pool_stats();
        println!("server {}: {} (pool {free}/{total} free)", id.short(), node.metrics.report());
    }
    println!("(CPU PJRT serializes executions; fused batches need b>1 decode artifacts — the");
    println!(" scheduler falls back to per-session execution when only b1 entries are compiled)");

    // ---- shared-prefix serving ------------------------------------------
    println!("\nshared-prefix arrival mix (sim, 8 clients, one 128-token template):");
    let mut cold = sim_swarm(false);
    let cold_r = cold.run_inference_concurrent_mix(8, 128, 32, 1).unwrap();
    let mut warm = sim_swarm(false);
    warm.prefix_cache = true;
    let warm_r = warm.run_inference_concurrent_mix(8, 128, 32, 1).unwrap();
    println!(
        "  time-to-first-token: {:.2}s cold -> {:.2}s with prefix cache ({} prefill hits)",
        cold_r.mean_ttft_s, warm_r.mean_ttft_s, warm_r.prefix_hits
    );

    println!("\nreal shared-prefix pool accounting (BLOOM-mini, 8 sessions, 128-token prompt):");
    let node =
        ServerNode::start("prefix", &home, rt.clone(), 0..g.n_layers, Precision::F16, false)?;
    let w = 128usize;
    let n_sessions = 8u64;
    let tokens: Vec<i32> = (0..w as i32).map(|i| i % 97).collect();
    let mut vals = vec![0f32; w * g.hidden];
    let mut rng = petals::config::Rng::new(17);
    for v in vals.iter_mut() {
        *v = (rng.f64() as f32 - 0.5) * 2.0;
    }
    let h0 = Tensor::from_f32(&[1, w, g.hidden], &vals);
    let h_step = Tensor::from_f32(&[1, 1, g.hidden], &vals[..g.hidden]);
    let mut page_costs: Vec<u64> = Vec::new();
    for sid in 1..=n_sessions {
        let (free_before, _) = node.pool_stats();
        node.open_session_with_prefix(sid, 1, w + 16, &tokens, w)?;
        node.prefill(sid, &h0)?;
        let (free_after, _) = node.pool_stats();
        page_costs.push(free_before - free_after);
    }
    let pages_first = page_costs[0];
    let pages_extra =
        page_costs[1..].iter().sum::<u64>() as f64 / (n_sessions - 1) as f64;
    let hits = node.metrics.prefix_hits.get();
    let hit_rate = hits as f64 / n_sessions as f64;
    println!("  pages: {pages_first} for the first session, {pages_extra:.1}/extra session");
    println!(
        "  prefix hits {hits}/{n_sessions} (prefill skips {}), shared pages {}",
        node.metrics.prefix_prefill_skips.get(),
        node.metrics.kv_pages_shared.get()
    );
    // aggregate decode throughput over the 8 shared sessions
    let t0 = std::time::Instant::now();
    let n_decode = 8usize;
    for step in 0..n_decode {
        for sid in 1..=n_sessions {
            node.step(sid, w + step, &h_step)?;
        }
    }
    let agg_steps_s = (n_decode as u64 * n_sessions) as f64 / t0.elapsed().as_secs_f64();
    println!("  aggregate decode: {agg_steps_s:.2} steps/s over {n_sessions} shared sessions");
    println!("  server: {}", node.metrics.report());

    let json = format!(
        "{{\n  \"clients\": {n_sessions},\n  \"prefix_tokens\": {w},\n  \
         \"pages_first_session\": {pages_first},\n  \"pages_per_extra_session\": {pages_extra:.2},\n  \
         \"prefix_hit_rate\": {hit_rate:.3},\n  \"prefill_skips\": {},\n  \
         \"cow_forks\": {},\n  \"aggregate_steps_per_s\": {agg_steps_s:.3},\n  \
         \"sim_ttft_cold_s\": {:.3},\n  \"sim_ttft_warm_s\": {:.3},\n  \
         \"gates\": {{\n    \"aggregate_steps_per_s\": {{\"dir\": \"higher\", \"pct\": 10}},\n    \
         \"prefix_hit_rate\": {{\"dir\": \"higher\", \"pct\": 10}}\n  }}\n}}\n",
        node.metrics.prefix_prefill_skips.get(),
        node.metrics.cow_forks.get(),
        cold_r.mean_ttft_s,
        warm_r.mean_ttft_s,
    );
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_prefix_cache.json".into());
    std::fs::write(&out, &json)?;
    println!("\nwrote {out}");
    Ok(())
}
