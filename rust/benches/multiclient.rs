//! §3.3 multi-client experiment: "For 12 servers with 100 Mbit/s
//! bandwidth and 100 ms latency, if 8 clients run inference
//! concurrently, each of them gets ≈20% slowdown compared to the case
//! when it runs inference alone."
//!
//! Part 1: the simulator at BLOOM-176B scale (client-count sweep).
//! Part 2: real concurrent clients (threads) against a real local swarm
//! at BLOOM-mini scale — contention through actual PJRT serialization.
//!
//! Run: `cargo bench --bench multiclient`

use petals::config::profiles::{NetworkProfile, SwarmPreset};
use petals::coordinator::client::{LocalHead, Sampler, SwarmGenerator};
use petals::coordinator::routing::RouteQuery;
use petals::coordinator::session::SessionConfig;
use petals::model::{ModelHome, Precision, Weights};
use petals::runtime::Runtime;
use petals::server::local::spawn_even_swarm;
use petals::sim::SwarmSim;
use std::sync::Arc;

fn main() -> petals::Result<()> {
    println!("multi-client slowdown (reproduction of §3.3)\n");
    println!("simulated 12-virtual swarm @ 100 Mbit/s, 100 ms RTT (BLOOM-176B):");
    println!("| clients | steps/s per client | slowdown vs solo |");
    println!("|---|---|---|");
    let solo = {
        let mut s =
            SwarmSim::build(SwarmPreset::TwelveVirtual.build(NetworkProfile::MBIT100_100MS, true), 0);
        s.run_inference(128, 32, 1).unwrap().steps_per_s
    };
    for n in [1usize, 2, 4, 8, 16] {
        let mut s =
            SwarmSim::build(SwarmPreset::TwelveVirtual.build(NetworkProfile::MBIT100_100MS, true), 0);
        let rates = s.run_inference_concurrent(n, 128, 32).unwrap();
        let mean: f64 = rates.iter().sum::<f64>() / rates.len() as f64;
        println!("| {n} | {mean:.2} | {:.0}% |", (1.0 - mean / solo) * 100.0);
    }
    println!("(paper: 8 clients -> ~20%)\n");

    // ---- real concurrent clients on BLOOM-mini --------------------------
    println!("real concurrent clients, BLOOM-mini local swarm (CPU PJRT):");
    let home = ModelHome::open("artifacts")?;
    let g = home.geometry().clone();
    let rt = Arc::new(Runtime::load_filtered(&home, |n| {
        n.contains("_b1_") || n.ends_with("_b1")
    })?);
    let cluster = Arc::new(spawn_even_swarm(&home, rt.clone(), 2, Precision::F16)?);
    let weights = Weights::load(&home, Precision::F16)?;
    let head = Arc::new(LocalHead::new(&home, rt, &weights)?);
    let cfg = SessionConfig {
        n_blocks: g.n_layers,
        batch: 1,
        prefill_width: 128,
        prefix_len: 8,
        max_new: 8,
        route: RouteQuery {
            n_blocks: g.n_layers,
            msg_bytes: (g.hidden * 4) as u64,
            beam_width: 8,
            queue_penalty_s: 0.05,
        },
        max_recoveries: 2,
    };

    println!("| clients | steps/s per client | slowdown |");
    println!("|---|---|---|");
    let mut solo_rate = 0.0;
    for n in [1usize, 2, 4] {
        let mut handles = Vec::new();
        for c in 0..n {
            let cluster = cluster.clone();
            let head = head.clone();
            let cfg = cfg.clone();
            handles.push(std::thread::spawn(move || {
                let generator = SwarmGenerator {
                    swarm: cluster.as_ref(),
                    head: head.as_ref(),
                    cfg,
                    sampler: Sampler::Greedy,
                };
                let prefix: Vec<i32> = (0..8).map(|i| (c * 31 + i) as i32 % 100).collect();
                let out = generator.generate(&[prefix], 8, 500 + c as u64).unwrap();
                out.steps as f64 / out.wall.as_secs_f64()
            }));
        }
        let rates: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let mean: f64 = rates.iter().sum::<f64>() / rates.len() as f64;
        if n == 1 {
            solo_rate = mean;
        }
        println!("| {n} | {mean:.2} | {:.0}% |", (1.0 - mean / solo_rate) * 100.0);
    }
    println!("(CPU PJRT serializes executions, so real contention here is the upper bound)");
    Ok(())
}
