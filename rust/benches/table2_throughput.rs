//! Table 2: generation throughput (tokens/s), 8-bit vs 16-bit weights,
//! batch ∈ {1, 8, 32}.
//!
//! Paper (8x A100, BLOOM-176B): int8 costs ~5% at batch 1 and becomes
//! negligible at batch 32. Here: one server hosting all BLOOM-mini
//! blocks on CPU PJRT; generation = embed → decode steps → lm_head, 20
//! tokens per request (matching the paper's "20 tokens").
//!
//! Deviation note (EXPERIMENTS.md): the interpret-mode Pallas int8
//! kernel pays a large CPU overhead that CUDA kernels do not, so the
//! absolute int8/16bit ratio is worse than the paper's 5%; the shape
//! that must hold is *overhead shrinking as batch grows* (per-batch
//! kernel overheads amortize).
//!
//! Run: `cargo bench --bench table2_throughput`

use petals::coordinator::client::LocalHead;
use petals::model::tensor::Tensor;
use petals::model::{ModelHome, Precision, Weights};
use petals::runtime::Runtime;
use petals::server::ServerNode;
use std::sync::Arc;

fn main() -> petals::Result<()> {
    let home = ModelHome::open("artifacts")?;
    let g = home.geometry().clone();
    let rt = Arc::new(Runtime::load(&home)?);
    let weights = Weights::load(&home, Precision::F16)?;
    let head = LocalHead::new(&home, rt.clone(), &weights)?;

    // 20 tokens per the paper; int8@b32 in interpret mode costs ~1 s per
    // block-step, so the b=32 cell uses fewer steps (tokens/s unaffected)
    let n_tokens = 20usize;
    println!("Table 2 (reproduction): generation throughput (tokens/s), BLOOM-mini on CPU PJRT\n");
    println!("| Weights | batch 1 | batch 8 | batch 32 |");
    println!("|---------|---------|---------|----------|");

    let mut rows = Vec::new();
    for (label, prec) in [("16-bit", Precision::F16), ("8-bit", Precision::Int8)] {
        let server = ServerNode::start(label, &home, rt.clone(), 0..g.n_layers, prec, false)?;
        let mut cells = Vec::new();
        for batch in [1usize, 8, 32] {
            let steps = if batch == 32 { 5 } else { n_tokens };
            let tput = generation_throughput(&home, &head, &server, batch, steps)?;
            cells.push(tput);
        }
        println!(
            "| {label} | {:.2} | {:.2} | {:.2} |",
            cells[0], cells[1], cells[2]
        );
        rows.push(cells);
    }
    println!("\nint8/16-bit throughput ratio per batch:");
    for (i, batch) in [1usize, 8, 32].iter().enumerate() {
        println!("  batch {batch}: {:.2}x", rows[1][i] / rows[0][i]);
    }
    println!("(paper shape: ratio -> 1.0 as batch grows)");
    Ok(())
}

/// tokens/s of `n_tokens` greedy decode steps at `batch` (prefill
/// excluded, matching the paper's generation measurement).
fn generation_throughput(
    home: &ModelHome,
    head: &LocalHead,
    server: &ServerNode,
    batch: usize,
    n_tokens: usize,
) -> petals::Result<f64> {
    let g = home.geometry();
    let mut rng = petals::config::Rng::new(batch as u64);
    let prefix_len = 8usize;
    let w = 128usize;
    let mut ids = vec![0i32; batch * w];
    for row in 0..batch {
        for s in 0..prefix_len {
            ids[row * w + s] = rng.below(g.vocab as u64) as i32;
        }
    }
    server.open_session(batch as u64, batch, 0)?;
    let h0 = head.embed(&Tensor::from_i32(&[batch, w], &ids))?;
    let h = server.prefill(batch as u64, &h0)?;
    let hidden = g.hidden;
    let mut last = {
        let src = h.as_f32();
        let mut v = Vec::with_capacity(batch * hidden);
        for bi in 0..batch {
            let off = (bi * w + prefix_len - 1) * hidden;
            v.extend_from_slice(&src[off..off + hidden]);
        }
        Tensor::from_f32(&[batch, hidden], &v)
    };

    let t0 = std::time::Instant::now();
    for step in 0..n_tokens {
        let logits = head.lm_head(&last)?;
        let next = petals::coordinator::client::Sampler::Greedy.sample(&logits);
        let h = head.embed(&Tensor::from_i32(&[batch, 1], &next))?;
        let out = server.step(batch as u64, prefix_len + step, &h)?;
        last = Tensor::from_f32(&[batch, hidden], out.as_f32());
    }
    let wall = t0.elapsed().as_secs_f64();
    server.close_session(batch as u64);
    Ok((batch * n_tokens) as f64 / wall)
}
