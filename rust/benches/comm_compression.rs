//! §3.1 communication compression ablation: dynamic blockwise int8 on
//! hidden states "halves the bandwidth requirements without any
//! noticeable effect on generation quality".
//!
//! Measures: codec throughput (Rust hot path), wire-size reduction,
//! roundtrip error, end-to-end effect in the simulator at each
//! bandwidth tier, and quality impact on real BLOOM-mini generation.
//!
//! Run: `cargo bench --bench comm_compression`

use petals::config::profiles::{NetworkProfile, SwarmPreset};
use petals::model::tensor::Tensor;
use petals::quant;
use petals::sim::SwarmSim;

fn main() -> petals::Result<()> {
    println!("§3.1 dynamic blockwise int8 communication compression\n");

    // ---- codec microbench -----------------------------------------------
    let sizes = [512usize, 14336, 14336 * 128];
    println!("| tensor (f32 elems) | quantize MB/s | dequantize MB/s | wire ratio | max rel err |");
    println!("|---|---|---|---|---|");
    let mut rng = petals::config::Rng::new(1);
    for n in sizes {
        let vals: Vec<f32> = (0..n).map(|_| (rng.f64() as f32 - 0.5) * 8.0).collect();
        let t = Tensor::from_f32(&[n], &vals);
        let iters = (50_000_000 / n).max(3);
        let t0 = std::time::Instant::now();
        let mut q = quant::quantize(&t);
        for _ in 1..iters {
            q = quant::quantize(&t);
        }
        let enc_s = t0.elapsed().as_secs_f64() / iters as f64;
        let t0 = std::time::Instant::now();
        let mut back = quant::dequantize(&q);
        for _ in 1..iters {
            back = quant::dequantize(&q);
        }
        let dec_s = t0.elapsed().as_secs_f64() / iters as f64;
        let mb = (n * 4) as f64 / 1e6;
        let err = vals
            .iter()
            .zip(back.as_f32())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
            / vals.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        println!(
            "| {n} | {:.0} | {:.0} | {:.3} | {:.4} |",
            mb / enc_s,
            mb / dec_s,
            q.wire_bytes() as f64 / (n * 4) as f64,
            err
        );
    }

    // ---- end-to-end effect per bandwidth tier ----------------------------
    println!("\nsimulated parallel forward tokens/s, compression on vs off:");
    println!("| network | raw f32 | compressed | speedup |");
    println!("|---|---|---|---|");
    for (label, net) in [
        ("1 Gbit/s, 5 ms", NetworkProfile::GBIT_5MS),
        ("100 Mbit/s, 5 ms", NetworkProfile::MBIT100_5MS),
        ("100 Mbit/s, 100 ms", NetworkProfile::MBIT100_100MS),
    ] {
        let run = |compress| {
            let mut s = SwarmSim::build(SwarmPreset::TwelveVirtual.build(net, compress), 0);
            s.run_forward(64, 128, 2).unwrap().tokens_per_s
        };
        let raw = run(false);
        let comp = run(true);
        println!("| {label} | {raw:.1} | {comp:.1} | {:.2}x |", comp / raw);
    }

    // ---- quality impact on real generation --------------------------------
    println!("\nreal BLOOM-mini: greedy tokens with raw vs compressed activations:");
    use petals::coordinator::client::{LocalHead, Sampler, SwarmGenerator};
    use petals::coordinator::routing::RouteQuery;
    use petals::coordinator::session::{ChainClient, SessionConfig};
    use petals::model::{ModelHome, Precision, Weights};
    use petals::runtime::Runtime;
    use std::sync::Arc;

    let home = ModelHome::open("artifacts")?;
    let g = home.geometry().clone();
    let rt = Arc::new(Runtime::load_filtered(&home, |n| {
        n.contains("_b1_") || n.ends_with("_b1")
    })?);
    let weights = Weights::load(&home, Precision::F16)?;
    let head = LocalHead::new(&home, rt.clone(), &weights)?;

    // wrapper that compresses every hidden-state transfer
    struct Compressing<C: ChainClient>(C);
    impl<C: ChainClient> ChainClient for Compressing<C> {
        fn discover(&self) -> Vec<petals::coordinator::routing::ServerView> {
            self.0.discover()
        }
        fn open_session(&self, s: petals::dht::NodeId, id: u64, b: usize, p: usize, m: usize) -> petals::Result<()> {
            self.0.open_session(s, id, b, p, m)
        }
        fn prefill(&self, s: petals::dht::NodeId, id: u64, h: &Tensor) -> petals::Result<Tensor> {
            let h = quant::dequantize(&quant::quantize(h));
            let out = self.0.prefill(s, id, &h)?;
            Ok(quant::dequantize(&quant::quantize(&out)))
        }
        fn step(&self, s: petals::dht::NodeId, id: u64, l: usize, h: &Tensor) -> petals::Result<Tensor> {
            let h = quant::dequantize(&quant::quantize(h));
            let out = self.0.step(s, id, l, &h)?;
            Ok(quant::dequantize(&quant::quantize(&out)))
        }
        fn close_session(&self, s: petals::dht::NodeId, id: u64) {
            self.0.close_session(s, id)
        }
        fn forward(&self, s: petals::dht::NodeId, h: &Tensor) -> petals::Result<Tensor> {
            self.0.forward(s, h)
        }
        fn backward(&self, s: petals::dht::NodeId, h: &Tensor, gr: &Tensor) -> petals::Result<Tensor> {
            self.0.backward(s, h, gr)
        }
    }

    let cfg = SessionConfig {
        n_blocks: g.n_layers,
        max_new: 16,
        route: RouteQuery {
            n_blocks: g.n_layers,
            msg_bytes: (g.hidden * 4) as u64,
            ..Default::default()
        },
        max_recoveries: 2,
        prefix_tokens: vec![],
    };
    let prefix: Vec<i32> = vec![9, 8, 7, 6, 5, 4, 3, 2];

    let raw_swarm =
        petals::server::local::spawn_even_swarm(&home, rt.clone(), 2, Precision::F16)?;
    let gen = SwarmGenerator { swarm: &raw_swarm, head: &head, cfg: cfg.clone(), sampler: Sampler::Greedy };
    let raw_tokens = gen.generate(&[prefix.clone()], 16, 1)?.tokens[0].clone();

    let comp_swarm = Compressing(petals::server::local::spawn_even_swarm(
        &home, rt, 2, Precision::F16,
    )?);
    let gen = SwarmGenerator { swarm: &comp_swarm, head: &head, cfg, sampler: Sampler::Greedy };
    let comp_tokens = gen.generate(&[prefix], 16, 2)?.tokens[0].clone();

    let agree = raw_tokens
        .iter()
        .zip(&comp_tokens)
        .filter(|(a, b)| a == b)
        .count();
    println!("  raw:        {raw_tokens:?}");
    println!("  compressed: {comp_tokens:?}");
    println!(
        "  agreement: {agree}/{} tokens — paper's 'no noticeable effect'",
        raw_tokens.len()
    );
    Ok(())
}
