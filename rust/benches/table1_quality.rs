//! Table 1: zero-shot quality, 16-bit vs 8-bit weights.
//!
//! Paper: HellaSwag/LAMBADA/WinoGrande accuracy for OPT-175B and
//! BLOOM-176B is preserved under LLM.int8() (Δavg <= 0.4 pt).
//!
//! Substitution (DESIGN.md): BLOOM-mini has synthetic weights, so public
//! benchmarks are meaningless. The reproduced *quantity* is the
//! quality delta between precisions on the same tasks:
//!
//! - three synthetic cloze "task families" (different prefix lengths /
//!   distributions standing in for the three benchmarks), scored as
//!   top-1 agreement of each precision with the f32 reference ranking,
//! - perplexity ratio int8/f16 over a held-out token stream.
//!
//! Shape target: agreement ~100%, PPL ratio ~1.0 (the paper's "little
//! effect on quality").
//!
//! Run: `cargo bench --bench table1_quality`

use petals::config::Rng;
use petals::coordinator::client::LocalHead;
use petals::model::tensor::Tensor;
use petals::model::{ModelHome, Precision, Weights};
use petals::runtime::Runtime;
use petals::server::ServerNode;
use std::sync::Arc;

fn main() -> petals::Result<()> {
    let home = ModelHome::open("artifacts")?;
    let g = home.geometry().clone();
    let rt = Arc::new(Runtime::load_filtered(&home, |n| {
        n.contains("_b1_") || n.ends_with("_b1")
    })?);
    let weights = Weights::load(&home, Precision::F16)?;
    let head = LocalHead::new(&home, rt.clone(), &weights)?;

    let f16 = ServerNode::start("f16", &home, rt.clone(), 0..g.n_layers, Precision::F16, false)?;
    let int8 = ServerNode::start("int8", &home, rt.clone(), 0..g.n_layers, Precision::Int8, false)?;

    println!("Table 1 (reproduction): zero-shot quality, 16-bit vs 8-bit weights");
    println!("(synthetic-cloze agreement with the f32 reference ranking; see bench header)\n");
    println!("| Task family | prompts | top-1 agreement (8-bit vs 16-bit) | mean |Δ logprob| |");
    println!("|---|---|---|---|");

    // three task families with different prefix statistics
    let families = [
        ("cloze-short (≈HellaSwag)", 6usize, 0u64),
        ("cloze-long (≈LAMBADA)", 16, 1),
        ("cloze-binary (≈WinoGrande)", 10, 2),
    ];
    let n_prompts = 20;
    let mut total_agree = 0.0;
    for (name, prefix_len, seed) in families {
        let mut rng = Rng::new(seed);
        let mut agree = 0usize;
        let mut dlp_sum = 0.0f64;
        for _ in 0..n_prompts {
            let ids: Vec<i32> =
                (0..prefix_len).map(|_| rng.below(g.vocab as u64) as i32).collect();
            let lf = last_logits(&head, &f16, &ids, g.hidden)?;
            let lq = last_logits(&head, &int8, &ids, g.hidden)?;
            let (af, _) = argmax(&lf);
            let (aq, _) = argmax(&lq);
            if af == aq {
                agree += 1;
            }
            // binary-choice margin for the WinoGrande-like family:
            // compare logprob of the reference top-1 under each precision
            let pf = logprob(&lf, af);
            let pq = logprob(&lq, af);
            dlp_sum += (pf - pq).abs() as f64;
        }
        let pct = 100.0 * agree as f64 / n_prompts as f64;
        total_agree += pct;
        println!("| {name} | {n_prompts} | {pct:.1}% | {:.4} |", dlp_sum / n_prompts as f64);
    }

    // perplexity ratio over a random token stream
    let mut rng = Rng::new(99);
    let mut nll_f = 0.0f64;
    let mut nll_q = 0.0f64;
    let mut count = 0usize;
    for _ in 0..10 {
        let ids: Vec<i32> = (0..12).map(|_| rng.below(g.vocab as u64) as i32).collect();
        for t in 4..ids.len() {
            let lf = last_logits(&head, &f16, &ids[..t], g.hidden)?;
            let lq = last_logits(&head, &int8, &ids[..t], g.hidden)?;
            nll_f -= logprob(&lf, ids[t] as usize) as f64;
            nll_q -= logprob(&lq, ids[t] as usize) as f64;
            count += 1;
        }
    }
    let ppl_f = (nll_f / count as f64).exp();
    let ppl_q = (nll_q / count as f64).exp();
    println!("\nperplexity: 16-bit {ppl_f:.3}, 8-bit {ppl_q:.3} (ratio {:.4})", ppl_q / ppl_f);
    println!("mean agreement {:.1}% — paper's Table 1 shape: ~no quality loss from int8", total_agree / 3.0);
    Ok(())
}

fn last_logits(
    head: &LocalHead,
    server: &ServerNode,
    ids: &[i32],
    hidden: usize,
) -> petals::Result<Vec<f32>> {
    let w = 128usize;
    let mut padded = vec![0i32; w];
    padded[..ids.len()].copy_from_slice(ids);
    let h0 = head.embed(&Tensor::from_i32(&[1, w], &padded))?;
    let h = server.forward(&h0)?;
    let p = ids.len();
    let last = Tensor::from_f32(&[1, hidden], &h.as_f32()[(p - 1) * hidden..p * hidden]);
    Ok(head.lm_head(&last)?.as_f32().to_vec())
}

fn argmax(row: &[f32]) -> (usize, f32) {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, &v)| (i, v))
        .unwrap()
}

fn logprob(logits: &[f32], idx: usize) -> f32 {
    let mx = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let z: f32 = logits.iter().map(|&x| (x - mx).exp()).sum();
    logits[idx] - mx - z.ln()
}
