//! Table 3 (Petals rows): sequential inference steps/s and parallel
//! forward tokens/s across swarm presets and network conditions.
//!
//! Every row of the paper's Table 3 except the offloading baseline
//! (see table3_offload). BLOOM-176B geometry through the calibrated
//! simulator (DESIGN.md §Substitutions): the same balancer/routing code
//! as the real servers, analytic device/network timing.
//!
//! Run: `cargo bench --bench table3_swarm`

use petals::config::profiles::{NetworkProfile, SwarmPreset};
use petals::sim::SwarmSim;

fn main() {
    println!("Table 3 (reproduction): single-batch inference and parallel forward\n");
    println!("| Setup | Bandwidth, RTT | inference seq 128 (steps/s) | seq 2048 | forward b=1 (tok/s) | b=64 |");
    println!("|---|---|---|---|---|---|");

    let nets = [
        ("1 Gbit/s, <5 ms", NetworkProfile::GBIT_5MS),
        ("100 Mbit/s, <5 ms", NetworkProfile::MBIT100_5MS),
        ("100 Mbit/s, 100 ms", NetworkProfile::MBIT100_100MS),
    ];

    // paper rows 1-3: 3 physical A100 servers
    for (label, net) in nets {
        row("Petals, 3 physical (A100)", label, SwarmPreset::ThreeA100, net);
    }
    // paper rows 4-6: 12 virtual servers
    for (label, net) in nets {
        row("Petals, 12 virtual", label, SwarmPreset::TwelveVirtual, net);
    }
    // paper row 7: 14 real-world heterogeneous servers (per-server nets)
    row(
        "Petals, 14 real-world",
        "heterogeneous",
        SwarmPreset::FourteenRealWorld,
        NetworkProfile::MBIT100_5MS, // default for servers without overrides
    );

    println!();
    println!("paper reference rows (BLOOM-176B, for shape comparison):");
    println!("  3 physical:  1.71/1.54 steps/s | 70.0/253.6 tok/s  (1 Gbit)");
    println!("               1.66/1.49         | 56.4/182.0        (100 Mbit 5ms)");
    println!("               1.23/1.11         | 19.7/112.2        (100 Mbit 100ms)");
    println!("  12 virtual:  1.24/1.06         | 37.9/180.0        (1 Gbit)");
    println!("               1.24/1.05         | 25.6/66.6         (100 Mbit 5ms)");
    println!("               0.57/0.53         | 5.8/44.3          (100 Mbit 100ms)");
    println!("  14 real:     0.83/0.79         | 32.6/179.4");
}

fn row(setup: &str, net_label: &str, preset: SwarmPreset, net: NetworkProfile) {
    let mut sim = SwarmSim::build(preset.build(net, true), 0);
    // sequence length 128 vs 2048: the sim charges prefill for the
    // prefix and the cache grows; steps/s measured over 32 decode steps
    let s128 = sim.run_inference(128, 32, 1).map(|r| r.steps_per_s).unwrap_or(0.0);
    let mut sim = SwarmSim::build(preset.build(net, true), 0);
    let s2048 = sim.run_inference(2048, 32, 1).map(|r| r.steps_per_s).unwrap_or(0.0);
    let mut sim = SwarmSim::build(preset.build(net, true), 0);
    let f1 = sim.run_forward(1, 128, 1).map(|r| r.tokens_per_s).unwrap_or(0.0);
    let mut sim = SwarmSim::build(preset.build(net, true), 0);
    let f64_ = sim.run_forward(64, 128, 4).map(|r| r.tokens_per_s).unwrap_or(0.0);
    println!("| {setup} | {net_label} | {s128:.2} | {s2048:.2} | {f1:.1} | {f64_:.1} |");
}
