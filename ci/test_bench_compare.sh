#!/usr/bin/env bash
# Self-test for ci/bench_compare.sh: skip / pass / fail paths in both the
# multi-file multi-metric mode and the legacy single-file mode, run in a
# throwaway git repo. Needs only bash + git + python3 (no toolchain), so
# it runs everywhere check.sh does — and first, because a broken gate
# silently waves regressions through.
#
#   ci/test_bench_compare.sh

set -euo pipefail
COMPARE="$(cd "$(dirname "$0")" && pwd)/bench_compare.sh"
TMP="$(mktemp -d)"
trap 'rm -rf "$TMP"' EXIT

fail() { echo "FAIL: $*" >&2; exit 1; }

mkrepo() {
    git -C "$1" init -q
    git -C "$1" -c user.email=ci@test -c user.name=ci commit -q --allow-empty -m init
}

commit_all() {
    git -C "$1" add -A
    git -C "$1" -c user.email=ci@test -c user.name=ci commit -q -m baseline
}

# ---- multi-file mode ---------------------------------------------------
REPO="$TMP/repo"
mkdir -p "$REPO/out"
mkrepo "$REPO"

# no baselines committed -> exit 0 (trajectory not started)
(cd "$REPO" && "$COMPARE" out) || fail "no-baseline multi mode must exit 0"

# two baselines: one throughput-like (higher), one latency-like (lower)
cat > "$REPO/BENCH_a.json" <<'EOF'
{"aggregate_steps_per_s": 100.0, "occupancy": 0.5,
 "gates": {"aggregate_steps_per_s": {"dir": "higher", "pct": 10},
           "occupancy": {"dir": "higher", "pct": 15}}}
EOF
cat > "$REPO/BENCH_b.json" <<'EOF'
{"lookup_ms": 20.0, "gates": {"lookup_ms": {"dir": "lower", "pct": 50}}}
EOF
commit_all "$REPO"

# fresh twin missing entirely -> SKIP, exit 0
(cd "$REPO" && "$COMPARE" out) || fail "missing fresh results must SKIP, not fail"

# both fresh and within bounds -> pass
cat > "$REPO/out/BENCH_a.json" <<'EOF'
{"aggregate_steps_per_s": 95.0, "occupancy": 0.48}
EOF
cat > "$REPO/out/BENCH_b.json" <<'EOF'
{"lookup_ms": 24.0}
EOF
(cd "$REPO" && "$COMPARE" out) || fail "in-bounds results must pass"

# higher-is-better metric under its floor -> exit 1
cat > "$REPO/out/BENCH_a.json" <<'EOF'
{"aggregate_steps_per_s": 80.0, "occupancy": 0.48}
EOF
if (cd "$REPO" && "$COMPARE" out); then
    fail "throughput drop below the floor must exit 1"
fi
cat > "$REPO/out/BENCH_a.json" <<'EOF'
{"aggregate_steps_per_s": 95.0, "occupancy": 0.48}
EOF

# lower-is-better metric above its ceiling -> exit 1
cat > "$REPO/out/BENCH_b.json" <<'EOF'
{"lookup_ms": 31.0}
EOF
if (cd "$REPO" && "$COMPARE" out); then
    fail "latency rise above the ceiling must exit 1"
fi
cat > "$REPO/out/BENCH_b.json" <<'EOF'
{"lookup_ms": 19.0}
EOF

# one skipped + one compared still passes (and says so)
rm "$REPO/out/BENCH_a.json"
OUT="$(cd "$REPO" && "$COMPARE" out)" || fail "skip+pass mix must exit 0"
echo "$OUT" | grep -q "SKIP BENCH_a.json" || fail "skip not reported"
echo "$OUT" | grep -q "1 file(s) compared" || fail "compared count wrong"

# a gated metric missing from the fresh result is a hard usage error
cat > "$REPO/out/BENCH_b.json" <<'EOF'
{"something_else": 1.0}
EOF
rc=0
(cd "$REPO" && "$COMPARE" out) || rc=$?
[[ "$rc" == 2 ]] || fail "missing gated metric must exit 2 (got $rc)"
rm "$REPO/out/BENCH_b.json"

# a baseline without gates is warned about, never enforced
cat > "$REPO/BENCH_c.json" <<'EOF'
{"metric": 1.0}
EOF
commit_all "$REPO"
cat > "$REPO/out/BENCH_c.json" <<'EOF'
{"metric": 0.0001}
EOF
OUT="$(cd "$REPO" && "$COMPARE" out)" || fail "gate-less baseline must not fail"
echo "$OUT" | grep -q "declares no gates" || fail "gate-less baseline not warned"

# ---- legacy single-file mode ------------------------------------------
LREPO="$TMP/legacy"
mkdir -p "$LREPO"
mkrepo "$LREPO"
echo '{"aggregate_steps_per_s": 50.0}' > "$LREPO/BENCH_x.json"

# no committed baseline -> skip
(cd "$LREPO" && "$COMPARE" BENCH_x.json) || fail "legacy no-baseline must exit 0"
commit_all "$LREPO"

# pass within the drop budget
echo '{"aggregate_steps_per_s": 47.0}' > "$LREPO/BENCH_x.json"
(cd "$LREPO" && "$COMPARE" BENCH_x.json aggregate_steps_per_s 10) \
    || fail "legacy in-bounds must pass"

# regression
echo '{"aggregate_steps_per_s": 40.0}' > "$LREPO/BENCH_x.json"
if (cd "$LREPO" && "$COMPARE" BENCH_x.json aggregate_steps_per_s 10); then
    fail "legacy regression must exit 1"
fi

# missing file -> usage error
rc=0
(cd "$LREPO" && "$COMPARE" BENCH_missing.json key 10) || rc=$?
[[ "$rc" == 2 ]] || fail "legacy missing file must exit 2 (got $rc)"

echo "bench_compare self-test: all paths ok"
