# Shared toolchain preflight — source from ci/*.sh (not executable on
# its own). A missing cargo/rustc should read as "install the toolchain"
# (exit 3), not as a bash failure halfway through a script.
# rust-toolchain.toml at the repo root pins the version rustup installs.

preflight_toolchain() {
    for tool in cargo rustc; do
        if ! command -v "$tool" >/dev/null 2>&1; then
            echo "error: '$tool' not found in PATH." >&2
            echo "hint: install via https://rustup.rs — rustup reads the pinned" >&2
            echo "      version from rust-toolchain.toml automatically." >&2
            exit 3
        fi
    done
}

# The workspace manifest is committed (rust/Cargo.toml + the vendored
# xla stub under vendor/xla), so a missing manifest now means a broken
# checkout, not a known gap. Call from inside rust/.
preflight_manifest() {
    if [[ ! -f Cargo.toml ]]; then
        echo "error: rust/Cargo.toml missing — this checkout is incomplete" >&2
        echo "       (the manifest is committed; see ROADMAP.md)." >&2
        exit 1
    fi
}

# Echo "--features artifact-tests" when the AOT artifacts exist — the
# tests that execute them are compile-gated so `cargo test` stays green
# on artifact-less environments (CI runners, fresh clones). Call from
# inside rust/.
preflight_test_features() {
    if [[ -f artifacts/manifest.json ]]; then
        echo "--features artifact-tests"
    fi
}
