# Shared toolchain preflight — source from ci/*.sh (not executable on
# its own). A missing cargo/rustc should read as "install the toolchain"
# (exit 3), not as a bash failure halfway through a script.
# rust-toolchain.toml at the repo root pins the version rustup installs.

preflight_toolchain() {
    for tool in cargo rustc; do
        if ! command -v "$tool" >/dev/null 2>&1; then
            echo "error: '$tool' not found in PATH." >&2
            echo "hint: install via https://rustup.rs — rustup reads the pinned" >&2
            echo "      version from rust-toolchain.toml automatically." >&2
            exit 3
        fi
    done
}

# The repo currently ships no rust/Cargo.toml (the seed's `xla` dependency
# is unvendored — see ROADMAP.md; authoring the manifest is the next
# CI-enabling step). Until it lands, cargo-based gates degrade with an
# explicit SKIP instead of a confusing "could not find Cargo.toml" error.
# Call from inside rust/.
preflight_manifest() {
    if [[ ! -f Cargo.toml ]]; then
        echo "SKIP: rust/Cargo.toml is not in this repo yet — the crate cannot be"
        echo "      built (unvendored 'xla' dependency; see ROADMAP.md). Exiting 0"
        echo "      so CI gates what exists; this becomes a real build gate the"
        echo "      moment a manifest is committed."
        exit 0
    fi
}
