#!/usr/bin/env bash
# CI gate: formatting, lints, docs, tests — in that order, fail fast.
#
#   ci/check.sh          # everything (fmt, clippy, doc, build, test)
#   ci/check.sh quick    # fmt + clippy only (pre-commit)
#
# Doc warnings are promoted to errors so `cargo doc --no-deps` regressions
# (broken intra-doc links, malformed headings) fail here instead of
# rotting silently.

set -euo pipefail
cd "$(dirname "$0")/../rust"

step() { printf '\n==> %s\n' "$*"; }

step "cargo fmt --check"
cargo fmt --all -- --check

step "cargo clippy -- -D warnings"
cargo clippy --all-targets -- -D warnings

if [[ "${1:-}" == "quick" ]]; then
    echo "quick mode: skipping doc/build/test"
    exit 0
fi

step "cargo doc --no-deps (warnings fatal)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

step "cargo build --release"
cargo build --release

step "cargo build --release --examples"
cargo build --release --examples

step "cargo test"
cargo test -q

echo
echo "all checks passed"
