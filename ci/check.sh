#!/usr/bin/env bash
# CI gate: formatting, lints, docs, tests — in that order, fail fast.
#
#   ci/check.sh            # everything (fmt, clippy, doc, build, test)
#   ci/check.sh quick      # fmt + clippy only (pre-commit)
#   ci/check.sh test-only  # build + test only (fast iteration loop)
#
# Doc warnings are promoted to errors so `cargo doc --no-deps` regressions
# (broken intra-doc links, malformed headings) fail here instead of
# rotting silently.

set -euo pipefail
CI_DIR="$(cd "$(dirname "$0")" && pwd)"
# shellcheck source=ci/preflight.sh
. "$CI_DIR/preflight.sh"
cd "$CI_DIR/../rust"

step() { printf '\n==> %s\n' "$*"; }

# the bench-compare gate's own tests run FIRST and need no toolchain —
# a broken gate silently waves perf regressions through
step "ci/test_bench_compare.sh"
"$CI_DIR/test_bench_compare.sh"

preflight_toolchain
preflight_manifest

MODE="${1:-}"

# artifact-gated suites switch on only when `make artifacts` has run
TEST_FEATURES="$(preflight_test_features)"
if [[ -n "$TEST_FEATURES" ]]; then
    echo "artifacts present: running with $TEST_FEATURES"
else
    echo "no artifacts: artifact-gated suites are compiled out (run 'make artifacts' to enable)"
fi

if [[ "$MODE" == "test-only" ]]; then
    # fast iteration loop: dev-profile tests only — a release build here
    # would be paid in full and never used by `cargo test`
    step "cargo test"
    # shellcheck disable=SC2086
    cargo test -q $TEST_FEATURES
    step "cargo test --test fault_injection --test churn (session durability gate)"
    # named gate: the fault-injection harness and the churn/migration
    # suite pin the durability invariants (bitwise recovery, zero-loss
    # drains) — run them explicitly so a test filter can never silently
    # drop them. Pure in-process mocks: no artifacts, no sockets.
    cargo test -q --test fault_injection --test churn
    step "cargo test --test observability (observability gate)"
    # named gate: Prometheus exposition validity + registry drift + the
    # 3-hop trace-coverage bar. In-process mocks and loopback sockets.
    cargo test -q --test observability
    step "cargo test --test spec_decode (speculative-decode gate)"
    # named gate: speculative greedy decode must stay bitwise identical
    # to per-token decode under every acceptance pattern, and verify
    # rounds must survive mid-round server kills. Pure in-process mocks.
    cargo test -q --test spec_decode
    step "cargo test --test rebalance (rebalance churn gate)"
    # named gate: live span moves must lose no sessions and change no
    # outputs, and the 256-node churn model must show rebalancing
    # beating static assignment. Deterministic in-process simulation.
    cargo test -q --test rebalance
    step "cargo test --test tenancy (multi-tenant gateway gate)"
    # named gate: auth/quota matrix, virtual-clock rate limits, the
    # unified error envelope, and the WFQ fairness bound (storming
    # tenant must not inflate well-behaved p99 TTFT beyond 2x).
    # Library-level + deterministic sim: no artifacts, no sockets.
    cargo test -q --test tenancy
    echo
    echo "test-only checks passed"
    exit 0
fi

step "cargo fmt --check"
cargo fmt --all -- --check

step "cargo clippy -- -D warnings"
# --features artifact-tests so the gated suites stay linted even where
# the artifacts themselves are absent (they only gate *running*)
cargo clippy --all-targets --features artifact-tests -- -D warnings

if [[ "$MODE" == "quick" ]]; then
    echo "quick mode: skipping doc/build/test"
    exit 0
fi

step "cargo doc --no-deps (warnings fatal)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

step "cargo build --release"
cargo build --release

step "cargo build --release --examples"
cargo build --release --examples

step "cargo test"
# shellcheck disable=SC2086
cargo test -q $TEST_FEATURES

step "cargo test --test fault_injection --test churn (session durability gate)"
# named gate (see test-only mode above): durability invariants must not
# be droppable by a test filter
cargo test -q --test fault_injection --test churn

step "cargo test --test observability (observability gate)"
# named gate (see test-only mode above): exposition validity, registry
# drift, and the per-hop trace coverage bar
cargo test -q --test observability

step "cargo test --test spec_decode (speculative-decode gate)"
# named gate (see test-only mode above): bitwise spec-vs-sequential
# greedy identity + mid-verify fault recovery
cargo test -q --test spec_decode

step "cargo test --test rebalance (rebalance churn gate)"
# named gate (see test-only mode above): zero-loss span moves + the
# rebalancing-beats-static churn bar at 256 nodes
cargo test -q --test rebalance

step "cargo test --test tenancy (multi-tenant gateway gate)"
# named gate (see test-only mode above): auth/quotas/rate limits, the
# unified envelope, and the adversarial-tenant WFQ fairness bound
cargo test -q --test tenancy

echo
echo "all checks passed"
