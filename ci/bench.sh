#!/usr/bin/env bash
# Perf trajectory: run the machine-readable benches and emit BENCH_*.json
# so successive PRs can be compared (see ci/bench_compare.sh for the
# multi-metric regression gate and ci/README.md for the baseline
# workflow).
#
#   ci/bench.sh [OUTDIR]     # default: the repo root
#
# Emits:
#   OUTDIR/BENCH_dht.json           — iterative-lookup hop count & latency,
#                                     churn reconvergence (sim + loopback
#                                     TCP); needs no artifacts
#   OUTDIR/BENCH_ragged.json        — ragged continuous batching: mixed-
#                                     length sim sweep (occupancy,
#                                     aggregate steps/s, p50 TTFT) plus
#                                     the session-durability timings
#                                     (migration_ms, resume_ttft_ms) and
#                                     the Prometheus self-scrape result
#                                     (scrape_ok, metrics_series) — the
#                                     latter tracked, not gated; needs no
#                                     artifacts — always produced
#   OUTDIR/BENCH_prefix_cache.json  — shared-prefix multiclient bench:
#                                     pages/session, hit rate,
#                                     aggregate_steps_per_s, sim TTFT;
#                                     needs the AOT artifacts
#                                     (`make artifacts`) — skipped with an
#                                     explicit message when they are absent

set -euo pipefail
# shellcheck source=ci/preflight.sh
. "$(dirname "$0")/preflight.sh"
OUTDIR="$(cd "${1:-$(dirname "$0")/..}" && pwd)"
cd "$(dirname "$0")/../rust"

preflight_toolchain
preflight_manifest

echo "==> cargo bench --bench dht_lookup (BENCH_OUT=$OUTDIR/BENCH_dht.json)"
BENCH_OUT="$OUTDIR/BENCH_dht.json" cargo bench --bench dht_lookup
test -s "$OUTDIR/BENCH_dht.json" || { echo "bench did not write BENCH_dht.json" >&2; exit 1; }
echo
echo "==> $OUTDIR/BENCH_dht.json"
cat "$OUTDIR/BENCH_dht.json"

# the multiclient bench runs its artifact-free ragged sim sweep FIRST and
# always writes BENCH_ragged.json; the real-swarm sections (and
# BENCH_prefix_cache.json) only run when the AOT artifacts exist
echo
echo "==> cargo bench --bench multiclient (BENCH_RAGGED_OUT=$OUTDIR/BENCH_ragged.json)"
BENCH_RAGGED_OUT="$OUTDIR/BENCH_ragged.json" \
BENCH_OUT="$OUTDIR/BENCH_prefix_cache.json" cargo bench --bench multiclient
test -s "$OUTDIR/BENCH_ragged.json" || { echo "bench did not write BENCH_ragged.json" >&2; exit 1; }
echo
echo "==> $OUTDIR/BENCH_ragged.json"
cat "$OUTDIR/BENCH_ragged.json"

# the bench stood up the Prometheus exporter and scraped itself over
# loopback TCP; surface the recorded outcome here (tracked, NOT gated —
# a fleeting port clash must not block a perf run, but the bench log
# should say so loudly)
if grep -q '"scrape_ok": true' "$OUTDIR/BENCH_ragged.json"; then
    echo
    echo "metrics self-scrape: ok ($(grep -o '"metrics_series": [0-9]*' "$OUTDIR/BENCH_ragged.json" | grep -o '[0-9]*') series)"
else
    echo
    echo "WARNING: metrics self-scrape failed (scrape_ok=false in BENCH_ragged.json)" >&2
fi

if [[ ! -f artifacts/manifest.json ]]; then
    echo
    echo "SKIP: rust/artifacts/manifest.json not found — BENCH_prefix_cache.json"
    echo "      needs the AOT artifacts ('make artifacts'); skipped in this"
    echo "      environment (BENCH_dht.json and BENCH_ragged.json are complete)."
    exit 0
fi

test -s "$OUTDIR/BENCH_prefix_cache.json" || { echo "bench did not write BENCH_prefix_cache.json" >&2; exit 1; }
echo
echo "==> $OUTDIR/BENCH_prefix_cache.json"
cat "$OUTDIR/BENCH_prefix_cache.json"
