#!/usr/bin/env bash
# Perf trajectory gate: run the shared-prefix multiclient bench and emit
# a machine-readable summary so successive PRs can be compared.
#
#   ci/bench.sh [OUT.json]     # default: BENCH_prefix_cache.json (cwd)
#
# The bench needs the AOT artifacts (`make artifacts`); it exercises the
# real paged pool + prefix cache at BLOOM-mini scale and the simulator at
# BLOOM-176B scale, then writes:
#   pages_first_session / pages_per_extra_session  — marginal-cost check
#   prefix_hit_rate, prefill_skips, cow_forks      — cache behaviour
#   aggregate_steps_per_s                          — multiclient decode
#   sim_ttft_cold_s / sim_ttft_warm_s              — TTFT win at scale

set -euo pipefail
OUT="${1:-$(pwd)/BENCH_prefix_cache.json}"
cd "$(dirname "$0")/../rust"

echo "==> cargo bench --bench multiclient (BENCH_OUT=$OUT)"
BENCH_OUT="$OUT" cargo bench --bench multiclient

test -s "$OUT" || { echo "bench did not write $OUT" >&2; exit 1; }
echo
echo "==> $OUT"
cat "$OUT"
