#!/usr/bin/env bash
# Bench-trajectory gate: diff a freshly produced BENCH_*.json against the
# committed previous run and fail on a significant regression.
#
#   ci/bench_compare.sh [NEW.json] [KEY] [MAX_DROP_PCT]
#
# Defaults: NEW = ./BENCH_prefix_cache.json, KEY = aggregate_steps_per_s,
# MAX_DROP_PCT = 10. The baseline is the file of the same *name* committed
# at the repo root at HEAD (`git show HEAD:<basename>`), so NEW may live
# in a scratch directory (CI writes fresh results to bench-out/ precisely
# so a skipped bench can never be compared against itself via the stale
# committed copy). Higher-is-better semantics: the gate fails when
# NEW[KEY] < BASE[KEY] * (1 - MAX_DROP_PCT/100).
#
# Exit codes: 0 pass (or no baseline yet — the first run *starts* the
# trajectory), 1 regression, 2 usage/parse error.

set -euo pipefail
NEW="${1:-BENCH_prefix_cache.json}"
KEY="${2:-aggregate_steps_per_s}"
MAX_DROP="${3:-10}"

if [[ ! -s "$NEW" ]]; then
    echo "error: '$NEW' missing or empty — run ci/bench.sh first" >&2
    exit 2
fi

REPO_ROOT="$(git -C "$(dirname "$NEW")" rev-parse --show-toplevel)"
REL="$(basename "$NEW")"

if ! BASE_JSON="$(git -C "$REPO_ROOT" show "HEAD:$REL" 2>/dev/null)"; then
    echo "no committed baseline for $REL at HEAD — skipping compare."
    echo "(commit a fresh $REL at the repo root to start the perf trajectory)"
    exit 0
fi

export BASE_JSON
python3 - "$NEW" "$KEY" "$MAX_DROP" <<'EOF'
import json, os, sys

new_path, key, max_drop = sys.argv[1], sys.argv[2], float(sys.argv[3])
try:
    new = json.load(open(new_path))
    base = json.loads(os.environ["BASE_JSON"])
except (OSError, json.JSONDecodeError) as e:
    print(f"error: cannot parse bench JSON: {e}", file=sys.stderr)
    sys.exit(2)
if key not in new or key not in base:
    print(f"error: key '{key}' missing (new: {key in new}, base: {key in base})", file=sys.stderr)
    sys.exit(2)
new_v, base_v = float(new[key]), float(base[key])
floor = base_v * (1 - max_drop / 100)
delta = (new_v / base_v - 1) * 100 if base_v else float("inf")
print(f"{key}: baseline {base_v:.3f} -> new {new_v:.3f} ({delta:+.1f}%)")
if new_v < floor:
    print(f"REGRESSION: {new_v:.3f} is below the {max_drop:.0f}% floor ({floor:.3f})",
          file=sys.stderr)
    sys.exit(1)
print(f"ok (floor {floor:.3f})")
EOF
