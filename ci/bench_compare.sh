#!/usr/bin/env bash
# Bench-trajectory gate: diff freshly produced BENCH_*.json files against
# the committed baselines and fail on any significant per-metric
# regression.
#
# Multi-file mode (what CI runs):
#   ci/bench_compare.sh NEWDIR
#     Iterates every BENCH_*.json committed at the repo root at HEAD.
#     For each baseline, the fresh twin is NEWDIR/<basename>; a missing
#     twin is reported as SKIP (that bench did not run — e.g. artifacts
#     absent), never a failure. Every metric listed in the baseline's
#     "gates" object is compared with its declared direction and
#     threshold:
#         "gates": { "<metric>": {"dir": "higher"|"lower", "pct": N} }
#     dir=higher fails when NEW < BASE * (1 - N/100)  (throughput-like);
#     dir=lower  fails when NEW > BASE * (1 + N/100)  (latency-like).
#     A baseline without a "gates" object contributes nothing (warned).
#
# Single-file mode (legacy interface, kept for scripts/tests):
#   ci/bench_compare.sh NEW.json KEY [MAX_DROP_PCT]
#     Gates one higher-is-better metric exactly as before.
#
# Exit codes: 0 pass (including "no baseline yet" — the first run STARTS
# the trajectory — and skipped files), 1 regression, 2 usage/parse error.

set -euo pipefail

usage() {
    echo "usage: ci/bench_compare.sh NEWDIR | NEW.json KEY [MAX_DROP_PCT]" >&2
    exit 2
}

[[ $# -ge 1 ]] || usage

# ---- single-file legacy mode ------------------------------------------
if [[ ! -d "$1" ]]; then
    NEW="$1"
    KEY="${2:-aggregate_steps_per_s}"
    MAX_DROP="${3:-10}"
    if [[ ! -s "$NEW" ]]; then
        echo "error: '$NEW' missing or empty — run ci/bench.sh first" >&2
        exit 2
    fi
    REPO_ROOT="$(git -C "$(dirname "$NEW")" rev-parse --show-toplevel)"
    REL="$(basename "$NEW")"
    if ! BASE_JSON="$(git -C "$REPO_ROOT" show "HEAD:$REL" 2>/dev/null)"; then
        echo "no committed baseline for $REL at HEAD — skipping compare."
        echo "(commit a fresh $REL at the repo root to start the perf trajectory)"
        exit 0
    fi
    export BASE_JSON
    python3 - "$NEW" "$KEY" "$MAX_DROP" <<'EOF'
import json, os, sys

new_path, key, max_drop = sys.argv[1], sys.argv[2], float(sys.argv[3])
try:
    new = json.load(open(new_path))
    base = json.loads(os.environ["BASE_JSON"])
except (OSError, json.JSONDecodeError) as e:
    print(f"error: cannot parse bench JSON: {e}", file=sys.stderr)
    sys.exit(2)
if key not in new or key not in base:
    print(f"error: key '{key}' missing (new: {key in new}, base: {key in base})", file=sys.stderr)
    sys.exit(2)
new_v, base_v = float(new[key]), float(base[key])
floor = base_v * (1 - max_drop / 100)
delta = (new_v / base_v - 1) * 100 if base_v else float("inf")
print(f"{key}: baseline {base_v:.3f} -> new {new_v:.3f} ({delta:+.1f}%)")
if new_v < floor:
    print(f"REGRESSION: {new_v:.3f} is below the {max_drop:.0f}% floor ({floor:.3f})",
          file=sys.stderr)
    sys.exit(1)
print(f"ok (floor {floor:.3f})")
EOF
    exit $?
fi

# ---- multi-file, multi-metric mode ------------------------------------
NEWDIR="$(cd "$1" && pwd)"
REPO_ROOT="$(git rev-parse --show-toplevel)"

BASELINES="$(git -C "$REPO_ROOT" ls-tree --name-only HEAD \
    | grep -E '^BENCH_[A-Za-z0-9_.-]*\.json$' || true)"
if [[ -z "$BASELINES" ]]; then
    echo "no BENCH_*.json baselines committed at the repo root — nothing to gate."
    echo "(commit fresh bench JSONs at the root to start the perf trajectory)"
    exit 0
fi

FAIL=0
COMPARED=0
for REL in $BASELINES; do
    NEW="$NEWDIR/$REL"
    if [[ ! -s "$NEW" ]]; then
        echo "SKIP $REL: no fresh result in $NEWDIR (that bench did not run)"
        continue
    fi
    if ! BASE_JSON="$(git -C "$REPO_ROOT" show "HEAD:$REL" 2>/dev/null)"; then
        echo "SKIP $REL: unreadable baseline at HEAD"
        continue
    fi
    export BASE_JSON
    set +e
    python3 - "$NEW" "$REL" <<'EOF'
import json, os, sys

new_path, rel = sys.argv[1], sys.argv[2]
try:
    new = json.load(open(new_path))
    base = json.loads(os.environ["BASE_JSON"])
except (OSError, json.JSONDecodeError) as e:
    print(f"error: {rel}: cannot parse bench JSON: {e}", file=sys.stderr)
    sys.exit(2)
gates = base.get("gates")
if not isinstance(gates, dict) or not gates:
    print(f"warn: {rel}: baseline declares no gates — nothing enforced")
    sys.exit(0)
failed = []
for key, spec in sorted(gates.items()):
    if not isinstance(spec, dict) or spec.get("dir") not in ("higher", "lower"):
        print(f"error: {rel}: gate '{key}' needs dir higher|lower", file=sys.stderr)
        sys.exit(2)
    try:
        pct = float(spec["pct"])
    except (KeyError, TypeError, ValueError):
        print(f"error: {rel}: gate '{key}' needs a numeric pct", file=sys.stderr)
        sys.exit(2)
    if key not in base:
        print(f"error: {rel}: gated metric '{key}' missing from baseline", file=sys.stderr)
        sys.exit(2)
    if key not in new:
        print(f"error: {rel}: gated metric '{key}' missing from fresh result", file=sys.stderr)
        sys.exit(2)
    base_v, new_v = float(base[key]), float(new[key])
    delta = (new_v / base_v - 1) * 100 if base_v else float("inf")
    if spec["dir"] == "higher":
        bound = base_v * (1 - pct / 100)
        bad = new_v < bound
        kind, word = "floor", "below"
    else:
        bound = base_v * (1 + pct / 100)
        bad = new_v > bound
        kind, word = "ceiling", "above"
    mark = "REGRESSION" if bad else "ok"
    print(f"  {mark:10s} {rel}:{key}: {base_v:.4g} -> {new_v:.4g} "
          f"({delta:+.1f}%, {kind} {bound:.4g})")
    if bad:
        failed.append(f"{key} {word} its {pct:.0f}% {kind}")
if failed:
    print(f"{rel}: {len(failed)} gated metric(s) regressed: {'; '.join(failed)}",
          file=sys.stderr)
    sys.exit(1)
EOF
    rc=$?
    set -e
    case $rc in
        0) COMPARED=$((COMPARED + 1)) ;;
        1) COMPARED=$((COMPARED + 1)); FAIL=1 ;;
        *) exit 2 ;;
    esac
done

if [[ "$FAIL" == 1 ]]; then
    echo "bench trajectory REGRESSED (see per-metric report above)" >&2
    exit 1
fi
echo "bench trajectory ok ($COMPARED file(s) compared)"
