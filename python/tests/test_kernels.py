"""Kernel-vs-reference correctness: the core Layer-1 signal.

Each Pallas kernel is checked against its pure-jnp oracle in
compile/kernels/ref.py, with hypothesis sweeping shapes and value
distributions (including adversarial cases: zeros, huge outliers, single
blocks, full caches).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attn_kernel
from compile.kernels import int8_matmul as int8_kernel
from compile.kernels import quantize as quant_kernel
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)

SETTINGS = dict(max_examples=12, deadline=None)


def _rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# blockwise quantization
# ---------------------------------------------------------------------------

class TestBlockwiseQuantize:
    @settings(**SETTINGS)
    @given(n_blocks=st.integers(1, 600), seed=st.integers(0, 2**31 - 1),
           scale=st.sampled_from([1e-3, 1.0, 100.0]))
    def test_matches_ref(self, n_blocks, seed, scale):
        x = _rand(seed, (n_blocks * ref.QUANT_BLOCK,), scale)
        q_k, s_k = quant_kernel.blockwise_quantize(x)
        q_r, s_r = ref.blockwise_quantize(x)
        np.testing.assert_array_equal(np.array(q_k), np.array(q_r))
        np.testing.assert_allclose(np.array(s_k), np.array(s_r), rtol=1e-6)

    @settings(**SETTINGS)
    @given(n_blocks=st.integers(1, 300), seed=st.integers(0, 2**31 - 1))
    def test_roundtrip_error_bound(self, n_blocks, seed):
        """|dequant(quant(x)) - x| <= absmax_block / 127 elementwise
        (half-ulp of the int8 grid, i.e. scale/2, plus float fuzz)."""
        x = _rand(seed, (n_blocks, ref.QUANT_BLOCK)).reshape(-1)
        q, s = quant_kernel.blockwise_quantize(x)
        back = quant_kernel.blockwise_dequantize(q, s, x.shape)
        err = np.abs(np.array(back) - np.array(x))
        bound = np.repeat(np.array(s), ref.QUANT_BLOCK) * 0.5 + 1e-7
        assert (err <= bound).all()

    def test_zeros(self):
        x = jnp.zeros((4 * ref.QUANT_BLOCK,))
        q, s = quant_kernel.blockwise_quantize(x)
        assert np.array(q).max() == 0
        back = quant_kernel.blockwise_dequantize(q, s, x.shape)
        np.testing.assert_array_equal(np.array(back), 0.0)

    def test_single_huge_outlier(self):
        x = jnp.zeros((ref.QUANT_BLOCK,)).at[13].set(1e20)
        q, s = quant_kernel.blockwise_quantize(x)
        back = quant_kernel.blockwise_dequantize(q, s, x.shape)
        np.testing.assert_allclose(float(back[13]), 1e20, rtol=1e-2)

    def test_multidim_shapes(self):
        x = _rand(3, (2, 4, 128))
        q, s = quant_kernel.blockwise_quantize(x)
        back = quant_kernel.blockwise_dequantize(q, s, x.shape)
        assert back.shape == x.shape
        q_r, s_r = ref.blockwise_quantize(x)
        np.testing.assert_array_equal(np.array(q), np.array(q_r))

    def test_compression_ratio(self):
        """Wire format is payload + scales: 1 + 4/64 bytes per f32 elem —
        the ~3.8x reduction the paper's 'halves bandwidth' claim (vs f16)
        corresponds to at f32."""
        n = 64 * 100
        q, s = quant_kernel.blockwise_quantize(_rand(0, (n,)))
        wire = q.size * 1 + s.size * 4
        assert wire / (n * 4) < 0.27


# ---------------------------------------------------------------------------
# int8 matmul with outlier decomposition
# ---------------------------------------------------------------------------

class TestInt8Matmul:
    def _setup(self, seed, m, k, n, n_outliers):
        kx, kw, ko = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = jax.random.normal(kx, (m, k))
        if n_outliers:
            cols = jax.random.choice(ko, k, (n_outliers,), replace=False)
            x = x.at[:, cols].mul(20.0)
        w = jax.random.normal(kw, (k, n)) * 0.05
        mask = ref.detect_outlier_columns(x)
        w_q, w_s, w_o = ref.int8_matmul_prepare_weights(w, mask)
        return x, w, w_q, w_s, w_o, mask

    @settings(**SETTINGS)
    @given(m=st.integers(1, 40), k=st.sampled_from([128, 256, 512]),
           n=st.sampled_from([128, 192, 384]), n_out=st.integers(0, 4),
           seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, m, k, n, n_out, seed):
        x, w, w_q, w_s, w_o, mask = self._setup(seed, m, k, n, n_out)
        y_ref = ref.int8_matmul(x, w_q, w_s, w_o, mask)
        y_ker = int8_kernel.int8_matmul(x, w_q, w_s, w_o,
                                        mask.astype(jnp.float32))
        np.testing.assert_allclose(np.array(y_ker), np.array(y_ref),
                                   rtol=3e-5, atol=3e-5)

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_close_to_exact(self, seed):
        """int8+outlier result stays within ~2% of the exact f32 matmul —
        the quality-preservation mechanism behind Table 1."""
        x, w, w_q, w_s, w_o, mask = self._setup(seed, 8, 512, 256, 3)
        y = int8_kernel.int8_matmul(x, w_q, w_s, w_o, mask.astype(jnp.float32))
        exact = x @ w
        rel = float(jnp.max(jnp.abs(y - exact)) / jnp.max(jnp.abs(exact)))
        assert rel < 0.02, rel

    def test_outliers_carried_exactly(self):
        """With ALL columns marked outlier the result is the exact matmul
        (pure f32 path)."""
        k = 128
        x = _rand(0, (4, k), 5.0)
        w = _rand(1, (k, 64), 0.1)
        mask = jnp.ones((k,), bool)
        w_q, w_s, w_o = ref.int8_matmul_prepare_weights(w, mask)
        y = int8_kernel.int8_matmul(x, w_q, w_s, w_o, mask.astype(jnp.float32))
        np.testing.assert_allclose(np.array(y), np.array(x @ w), rtol=1e-5)

    def test_no_outliers(self):
        k = 256
        x = _rand(0, (4, k))
        w = _rand(1, (k, 64), 0.1)
        mask = jnp.zeros((k,), bool)
        w_q, w_s, w_o = ref.int8_matmul_prepare_weights(w, mask)
        y_ker = int8_kernel.int8_matmul(x, w_q, w_s, w_o, mask.astype(jnp.float32))
        y_ref = ref.int8_matmul(x, w_q, w_s, w_o, mask)
        np.testing.assert_allclose(np.array(y_ker), np.array(y_ref),
                                   rtol=3e-5, atol=3e-5)

    def test_zero_input(self):
        k = 128
        w = _rand(1, (k, 64))
        mask = jnp.zeros((k,), bool)
        w_q, w_s, w_o = ref.int8_matmul_prepare_weights(w, mask)
        y = int8_kernel.int8_matmul(jnp.zeros((2, k)), w_q, w_s, w_o,
                                    mask.astype(jnp.float32))
        np.testing.assert_array_equal(np.array(y), 0.0)

    def test_row_quantize_matches_ref(self):
        x = _rand(5, (10, 256), 3.0)
        mask = jnp.zeros((256,)).at[5].set(1.0)
        q, s = int8_kernel.row_quantize(x, mask)
        x_reg = np.array(x) * (1 - np.array(mask))[None, :]
        absmax = np.abs(x_reg).max(axis=1)
        np.testing.assert_allclose(np.array(s), absmax / 127.0, rtol=1e-6)
        assert np.abs(np.array(q)).max() <= 127


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

class TestDecodeAttention:
    @settings(**SETTINGS)
    @given(b=st.integers(1, 4), h=st.sampled_from([1, 2, 4, 8, 16]),
           s=st.sampled_from([64, 128, 256, 384]),
           d=st.sampled_from([32, 64]),
           frac=st.floats(0.01, 1.0), seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, b, h, s, d, frac, seed):
        keys = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(keys[0], (b, h, d))
        k = jax.random.normal(keys[1], (b, h, s, d))
        v = jax.random.normal(keys[2], (b, h, s, d))
        clen = max(1, int(s * frac))
        y_ref = ref.decode_attention(q, k, v, jnp.int32(clen))
        y_ker = attn_kernel.decode_attention(q, k, v, jnp.int32(clen))
        np.testing.assert_allclose(np.array(y_ker), np.array(y_ref),
                                   rtol=2e-5, atol=2e-5)

    def test_cache_len_one_returns_current_v(self):
        """With a single valid position, softmax is a delta: out == v[0]."""
        b, h, s, d = 1, 8, 128, 64
        keys = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(keys[0], (b, h, d))
        k = jax.random.normal(keys[1], (b, h, s, d))
        v = jax.random.normal(keys[2], (b, h, s, d))
        y = attn_kernel.decode_attention(q, k, v, jnp.int32(1))
        np.testing.assert_allclose(np.array(y), np.array(v[:, :, 0]),
                                   rtol=1e-5, atol=1e-5)

    def test_garbage_beyond_cache_len_ignored(self):
        b, h, s, d = 1, 8, 256, 64
        keys = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(keys[0], (b, h, d))
        k = jax.random.normal(keys[1], (b, h, s, d))
        v = jax.random.normal(keys[2], (b, h, s, d))
        clen = 77
        y1 = attn_kernel.decode_attention(q, k, v, jnp.int32(clen))
        k2 = k.at[:, :, clen:].set(1e6)
        v2 = v.at[:, :, clen:].set(-1e6)
        y2 = attn_kernel.decode_attention(q, k2, v2, jnp.int32(clen))
        np.testing.assert_allclose(np.array(y1), np.array(y2), rtol=1e-6)

    def test_alibi_recency_bias(self):
        """With identical K, ALiBi must weight recent positions higher."""
        b, h, s, d = 1, 8, 128, 64
        q = jnp.ones((b, h, d))
        k = jnp.ones((b, h, s, d))
        # v encodes its position index in component 0
        v = jnp.zeros((b, h, s, d)).at[:, :, :, 0].set(
            jnp.arange(s, dtype=jnp.float32))
        clen = 100
        y = attn_kernel.decode_attention(q, k, v, jnp.int32(clen))
        # expectation of position under ALiBi-weighted softmax must exceed
        # the uniform mean (clen-1)/2
        assert float(y[0, -1, 0]) > (clen - 1) / 2

    def test_probs_convexity(self):
        """Output is a convex combination of valid v rows."""
        b, h, s, d = 2, 4, 128, 32
        keys = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(keys[0], (b, h, d)) * 3
        k = jax.random.normal(keys[1], (b, h, s, d))
        v = jax.random.normal(keys[2], (b, h, s, d))
        clen = 50
        y = np.array(attn_kernel.decode_attention(q, k, v, jnp.int32(clen)))
        vv = np.array(v[:, :, :clen])
        assert (y <= vv.max(axis=2) + 1e-5).all()
        assert (y >= vv.min(axis=2) - 1e-5).all()
