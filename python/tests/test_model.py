"""Layer-2 model tests: shapes, prefill/decode agreement, int8 parity,
backward correctness, generation determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(hidden=128, n_layers=2, n_heads=4, vocab=256, max_seq=128)


@pytest.fixture(scope="module")
def params():
    return M.init_model_params(CFG, seed=0)


@pytest.fixture(scope="module")
def flat0(params):
    return [params["blocks"][0][n] for n in M.BLOCK_PARAM_NAMES]


def _embed(params, ids):
    return M.embed_fn(CFG, ids, params["embedding"],
                      params["ln_emb_g"], params["ln_emb_b"])


class TestShapes:
    def test_embed(self, params):
        ids = jnp.zeros((3, 7), jnp.int32)
        assert _embed(params, ids).shape == (3, 7, CFG.hidden)

    def test_prefill(self, params, flat0):
        h = jnp.zeros((2, 9, CFG.hidden))
        out, k, v = M.block_prefill_fn(CFG, h, *flat0)
        assert out.shape == (2, 9, CFG.hidden)
        assert k.shape == (2, CFG.n_heads, 9, CFG.head_dim)
        assert v.shape == k.shape

    def test_decode(self, params, flat0):
        b, c = 2, 64
        h = jnp.zeros((b, 1, CFG.hidden))
        kc = jnp.zeros((b, CFG.n_heads, c, CFG.head_dim))
        out, k2, v2 = M.block_decode_fn(
            CFG, h, kc, kc, jnp.array([3], jnp.int32), *flat0)
        assert out.shape == (b, 1, CFG.hidden)
        assert k2.shape == kc.shape

    def test_lm_head(self, params):
        h = jnp.zeros((5, CFG.hidden))
        logits = M.lm_head_fn(CFG, h, params["ln_f_g"], params["ln_f_b"],
                              params["embedding"])
        assert logits.shape == (5, CFG.vocab)

    def test_block_bytes_int8_halves(self):
        """The memory accounting behind '44 nodes -> 22 nodes'."""
        f16 = CFG.block_bytes("f16")
        i8 = CFG.block_bytes("int8")
        assert 0.25 < i8 / f16 < 0.35  # f32 baseline: int8 is ~4x smaller


class TestPrefillDecodeAgreement:
    def test_stepwise_equals_prefill(self, params, flat0):
        """Running tokens one-by-one through decode must reproduce the
        full-prefix prefill — the invariant Petals sessions rely on when
        replaying inputs to replacement servers."""
        ids = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, CFG.vocab)
        h = _embed(params, ids)
        full, _, _ = M.block_prefill_fn(CFG, h, *flat0)

        c = 32
        kc = jnp.zeros((1, CFG.n_heads, c, CFG.head_dim))
        vc = jnp.zeros((1, CFG.n_heads, c, CFG.head_dim))
        for t in range(12):
            out, kc, vc = M.block_decode_fn(
                CFG, h[:, t:t + 1], kc, vc, jnp.array([t], jnp.int32), *flat0)
            np.testing.assert_allclose(np.array(out[:, 0]),
                                       np.array(full[:, t]),
                                       rtol=5e-4, atol=5e-4)

    def test_prefill_then_decode(self, params, flat0):
        ids = jax.random.randint(jax.random.PRNGKey(2), (2, 10), 0, CFG.vocab)
        h = _embed(params, ids)
        full, _, _ = M.block_prefill_fn(CFG, h, *flat0)
        part, k9, v9 = M.block_prefill_fn(CFG, h[:, :9], *flat0)
        c = 64
        kc = jnp.zeros((2, CFG.n_heads, c, CFG.head_dim)).at[:, :, :9].set(k9)
        vc = jnp.zeros((2, CFG.n_heads, c, CFG.head_dim)).at[:, :, :9].set(v9)
        out, _, _ = M.block_decode_fn(CFG, h[:, 9:10], kc, vc,
                                      jnp.array([9], jnp.int32), *flat0)
        np.testing.assert_allclose(np.array(out[:, 0]), np.array(full[:, 9]),
                                   rtol=5e-4, atol=5e-4)


class TestInt8Parity:
    def test_block_outputs_close(self, params, flat0):
        ids = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0, CFG.vocab)
        masks = M.calibrate_outlier_masks(CFG, params, ids)
        h = _embed(params, ids)
        f32_out, _, _ = M.block_prefill_fn(CFG, h, *flat0)
        p8 = M.prepare_int8_params(params["blocks"][0], masks[0])
        i8_out, _, _ = M.block_prefill_int8_fn(
            CFG, h, *M.flatten_int8_params(p8))
        rel = float(jnp.max(jnp.abs(i8_out - f32_out)) /
                    jnp.max(jnp.abs(f32_out)))
        assert rel < 0.02, rel

    def test_decode_outputs_close(self, params, flat0):
        ids = jax.random.randint(jax.random.PRNGKey(4), (1, 16), 0, CFG.vocab)
        masks = M.calibrate_outlier_masks(CFG, params, ids)
        h = _embed(params, ids)
        c = 32
        _, k, v = M.block_prefill_fn(CFG, h[:, :8], *flat0)
        kc = jnp.zeros((1, CFG.n_heads, c, CFG.head_dim)).at[:, :, :8].set(k)
        vc = jnp.zeros((1, CFG.n_heads, c, CFG.head_dim)).at[:, :, :8].set(v)
        clen = jnp.array([8], jnp.int32)
        f32_out, _, _ = M.block_decode_fn(CFG, h[:, 8:9], kc, vc, clen, *flat0)
        p8 = M.prepare_int8_params(params["blocks"][0], masks[0])
        i8_out, _, _ = M.block_decode_int8_fn(
            CFG, h[:, 8:9], kc, vc, clen, *M.flatten_int8_params(p8))
        rel = float(jnp.max(jnp.abs(i8_out - f32_out)) /
                    jnp.max(jnp.abs(f32_out)))
        assert rel < 0.02, rel

    def test_greedy_tokens_identical(self, params):
        """Table 1's qualitative claim at mini scale: int8 preserves the
        argmax for most steps. We check the stronger whole-model parity of
        logits within 2% instead of task accuracy here (benches do the
        task-level version)."""
        ids = jax.random.randint(jax.random.PRNGKey(5), (1, 12), 0, CFG.vocab)
        logits = M.forward_full(CFG, params, ids)
        masks = M.calibrate_outlier_masks(CFG, params, ids)
        h = _embed(params, ids)
        for bp, mask in zip(params["blocks"], masks):
            p8 = M.prepare_int8_params(bp, mask)
            h, _, _ = M.block_prefill_int8_fn(CFG, h, *M.flatten_int8_params(p8))
        x = M._layernorm(h, params["ln_f_g"], params["ln_f_b"])
        logits8 = x @ params["embedding"].T
        rel = float(jnp.max(jnp.abs(logits8 - logits)) /
                    jnp.max(jnp.abs(logits)))
        assert rel < 0.05, rel


class TestBackward:
    def test_matches_autodiff(self, params, flat0):
        h = jax.random.normal(jax.random.PRNGKey(6), (2, 8, CFG.hidden)) * 0.5
        g = jax.random.normal(jax.random.PRNGKey(7), (2, 8, CFG.hidden))
        got = M.block_bwd_fn(CFG, h, g, *flat0)

        def scalar_fn(hh):
            out, _, _ = M.block_prefill_fn(CFG, hh, *flat0)
            return jnp.sum(out * g)
        want = jax.grad(scalar_fn)(h)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=1e-4, atol=1e-4)

    def test_grad_flows_through_all_positions(self, params, flat0):
        """Causality: grad at input position t must be influenced only by
        output positions >= t; position 0 must receive grad from all."""
        h = jax.random.normal(jax.random.PRNGKey(8), (1, 6, CFG.hidden)) * 0.5
        g_last = jnp.zeros_like(h).at[:, -1].set(1.0)
        gin = M.block_bwd_fn(CFG, h, g_last, *flat0)
        assert float(jnp.abs(gin[:, 0]).max()) > 0  # attention mixes back
        g_first = jnp.zeros_like(h).at[:, 0].set(1.0)
        gin2 = M.block_bwd_fn(CFG, h, g_first, *flat0)
        # causal: grad wrt positions > 0 comes only through position-0
        # output => small but nonzero residual path; position 5 gets
        # nothing except via... nothing (no forward path 5 -> 0).
        np.testing.assert_allclose(np.array(gin2[:, 5]), 0.0, atol=1e-6)


class TestGeneration:
    def test_deterministic(self, params):
        ids = jax.random.randint(jax.random.PRNGKey(9), (1, 5), 0, CFG.vocab)
        a = M.generate_greedy(CFG, params, ids, 6)
        b = M.generate_greedy(CFG, params, ids, 6)
        np.testing.assert_array_equal(np.array(a), np.array(b))

    def test_tokens_in_vocab(self, params):
        ids = jax.random.randint(jax.random.PRNGKey(10), (2, 4), 0, CFG.vocab)
        out = np.array(M.generate_greedy(CFG, params, ids, 5))
        assert out.shape == (2, 5)
        assert (out >= 0).all() and (out < CFG.vocab).all()
