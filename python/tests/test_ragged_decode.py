"""Ragged decode correctness: the numerics contract behind cross-row
ragged continuous batching.

The server fuses decode steps from sessions at *different* cache depths
into one `block_decode_ragged_*` call. That is only sound if every row
of the ragged batch is bitwise identical to running that row alone
through the uniform decode path — padding and the other rows must be
causally invisible. These tests pin exactly that, at both the kernel
layer (ragged_decode_attention vs decode_attention) and the block layer
(block_decode_ragged_fn vs block_decode_fn), including the multi-tile
case where a short row's tail tile is fully masked.

No hypothesis dependency (the container lacks it); shapes are swept
explicitly.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.kernels import attention as attn_kernel

jax.config.update("jax_enable_x64", False)

CFG = M.ModelConfig(hidden=64, n_layers=2, n_heads=4, vocab=128, max_seq=64)


def _rand(key, shape, scale=0.5):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


def _flat(cfg, seed=0):
    params = M.init_model_params(cfg, seed=seed)
    return [params["blocks"][0][n] for n in M.BLOCK_PARAM_NAMES]


class TestRaggedAttentionKernel:
    def test_each_row_matches_solo_uniform_kernel(self):
        b, h, s, d = 4, 4, 64, 16
        q = _rand(1, (b, h, d))
        k = _rand(2, (b, h, s, d))
        v = _rand(3, (b, h, s, d))
        lens = jnp.array([1, 7, 33, 64], jnp.int32)
        ragged = attn_kernel.ragged_decode_attention(q, k, v, lens)
        for r in range(b):
            solo = attn_kernel.decode_attention(
                q[r : r + 1], k[r : r + 1], v[r : r + 1], lens[r])
            np.testing.assert_array_equal(
                np.asarray(ragged[r]), np.asarray(solo[0]),
                err_msg=f"row {r} (len {lens[r]}) diverged from its solo run")

    def test_uniform_lens_match_uniform_kernel_whole_batch(self):
        b, h, s, d = 3, 4, 64, 16
        q = _rand(4, (b, h, d))
        k = _rand(5, (b, h, s, d))
        v = _rand(6, (b, h, s, d))
        uniform = attn_kernel.decode_attention(q, k, v, 9)
        ragged = attn_kernel.ragged_decode_attention(
            q, k, v, jnp.full((b,), 9, jnp.int32))
        np.testing.assert_array_equal(np.asarray(ragged), np.asarray(uniform))

    def test_multitile_short_row_tail_fully_masked(self):
        # S=256 with BS=128 gives two seq tiles; a row with len <= 128
        # must fold a fully masked second tile without contaminating the
        # softmax (exp(NEG_INF - m) == 0 exactly).
        b, h, s, d = 2, 4, 256, 8
        q = _rand(7, (b, h, d))
        k = _rand(8, (b, h, s, d))
        v = _rand(9, (b, h, s, d))
        lens = jnp.array([5, 200], jnp.int32)
        ragged = attn_kernel.ragged_decode_attention(q, k, v, lens)
        for r in range(b):
            solo = attn_kernel.decode_attention(
                q[r : r + 1], k[r : r + 1], v[r : r + 1], lens[r])
            np.testing.assert_array_equal(np.asarray(ragged[r]), np.asarray(solo[0]))

    def test_garbage_beyond_row_len_is_invisible(self):
        # positions >= lens[r] may hold stale values in the paged pool's
        # gather; they must not change the row's output
        b, h, s, d = 2, 4, 64, 8
        q = _rand(10, (b, h, d))
        k = _rand(11, (b, h, s, d))
        v = _rand(12, (b, h, s, d))
        lens = jnp.array([3, 17], jnp.int32)
        clean = attn_kernel.ragged_decode_attention(q, k, v, lens)
        k_dirty = k.at[0, :, 3:, :].set(1e6).at[1, :, 17:, :].set(-1e6)
        v_dirty = v.at[0, :, 3:, :].set(-123.0).at[1, :, 17:, :].set(77.0)
        dirty = attn_kernel.ragged_decode_attention(q, k_dirty, v_dirty, lens)
        np.testing.assert_array_equal(np.asarray(clean), np.asarray(dirty))


class TestRaggedBlockDecode:
    def test_each_row_matches_solo_uniform_block(self):
        flat = _flat(CFG)
        b, hh, c, d = 3, CFG.n_heads, CFG.max_seq, CFG.head_dim
        h_in = _rand(20, (b, 1, CFG.hidden))
        k = _rand(21, (b, hh, c, d))
        v = _rand(22, (b, hh, c, d))
        lens = jnp.array([2, 11, 40], jnp.int32)
        h_out, k_out, v_out = M.block_decode_ragged_fn(CFG, h_in, k, v, lens, *flat)
        for r in range(b):
            sh, sk, sv = M.block_decode_fn(
                CFG, h_in[r : r + 1], k[r : r + 1], v[r : r + 1],
                jnp.array([lens[r]], jnp.int32), *flat)
            np.testing.assert_array_equal(
                np.asarray(h_out[r]), np.asarray(sh[0]),
                err_msg=f"row {r} hidden diverged")
            np.testing.assert_array_equal(
                np.asarray(k_out[r]), np.asarray(sk[0]),
                err_msg=f"row {r} K cache diverged")
            np.testing.assert_array_equal(
                np.asarray(v_out[r]), np.asarray(sv[0]),
                err_msg=f"row {r} V cache diverged")

    def test_cache_write_lands_per_row(self):
        flat = _flat(CFG)
        b, hh, c, d = 2, CFG.n_heads, CFG.max_seq, CFG.head_dim
        h_in = _rand(23, (b, 1, CFG.hidden))
        k = jnp.zeros((b, hh, c, d))
        v = jnp.zeros((b, hh, c, d))
        lens = jnp.array([4, 19], jnp.int32)
        _, k_out, v_out = M.block_decode_ragged_fn(CFG, h_in, k, v, lens, *flat)
        for r, ln in enumerate([4, 19]):
            assert np.any(np.asarray(k_out[r, :, ln, :]) != 0.0), f"row {r}: no K write"
            assert np.any(np.asarray(v_out[r, :, ln, :]) != 0.0), f"row {r}: no V write"
            # every other column untouched (bitwise select, not arithmetic)
            mask = np.ones(c, bool)
            mask[ln] = False
            np.testing.assert_array_equal(np.asarray(k_out[r, :, mask, :]), 0.0)

    def test_prefill_rows_batch_invariant(self):
        # the multi-prompt API path prefills N rows in one call and the
        # bitwise fused-vs-serial contract compares against batch-1
        # prefills — so prefill rows must be batch-invariant too
        flat = _flat(CFG)
        h = _rand(40, (4, 16, CFG.hidden))
        full, fk, fv = M.block_prefill_fn(CFG, h, *flat)
        for r in range(4):
            sh, sk, sv = M.block_prefill_fn(CFG, h[r : r + 1], *flat)
            np.testing.assert_array_equal(np.asarray(full[r]), np.asarray(sh[0]))
            np.testing.assert_array_equal(np.asarray(fk[r]), np.asarray(sk[0]))
            np.testing.assert_array_equal(np.asarray(fv[r]), np.asarray(sv[0]))

    def test_int8_ragged_matches_solo_int8(self):
        params = M.init_model_params(CFG, seed=0)
        key = jax.random.PRNGKey(99)
        calib = jax.random.randint(key, (2, 16), 0, CFG.vocab)
        masks = M.calibrate_outlier_masks(CFG, params, calib)
        flat8 = M.flatten_int8_params(
            M.prepare_int8_params(params["blocks"][0], masks[0]))
        b, hh, c, d = 2, CFG.n_heads, CFG.max_seq, CFG.head_dim
        h_in = _rand(30, (b, 1, CFG.hidden))
        k = _rand(31, (b, hh, c, d))
        v = _rand(32, (b, hh, c, d))
        lens = jnp.array([6, 25], jnp.int32)
        h_out, _, _ = M.block_decode_ragged_int8_fn(CFG, h_in, k, v, lens, *flat8)
        for r in range(b):
            sh, _, _ = M.block_decode_int8_fn(
                CFG, h_in[r : r + 1], k[r : r + 1], v[r : r + 1],
                jnp.array([lens[r]], jnp.int32), *flat8)
            np.testing.assert_array_equal(np.asarray(h_out[r]), np.asarray(sh[0]))
