"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here, written
with plain jax.numpy only (no pallas). pytest checks kernel-vs-ref
allclose over randomized shapes/dtypes (see python/tests/) — this is the
core correctness signal for Layer 1.

The quantization formats defined here are ALSO implemented in Rust
(rust/src/quant/) for the communication-compression path; the layouts
must stay bit-identical across the three implementations:

  dynamic blockwise int8 (Dettmers et al., 2022b "8-bit optimizers"):
    - flatten tensor, split into blocks of QUANT_BLOCK elements
    - scale_b = max(|x_b|) / 127  (absmax per block)
    - q_b = round(x_b / scale_b) as int8, scales kept as f32

  LLM.int8() outlier decomposition (Dettmers et al., 2022a):
    - columns of X whose absmax exceeds OUTLIER_THRESHOLD are "outliers"
    - X @ W = X[:, reg] @ W[reg, :] in int8 + X[:, out] @ W[out, :] in f32
    - int8 path quantizes X row-wise and W column-wise (vector-wise
      quantization in the paper)
"""

import jax.numpy as jnp

# Block size for dynamic blockwise quantization. 64 elements per block is
# small enough for <0.5% relative error on LLM hidden states and keeps the
# scale overhead at 6.25% (4 bytes per 64 int8 payload bytes).
QUANT_BLOCK = 64

# Activation-magnitude threshold that marks a feature dimension as an
# outlier column (the paper uses 6.0 for real LLM activations).
OUTLIER_THRESHOLD = 6.0


# ---------------------------------------------------------------------------
# Dynamic blockwise quantization (communication compression)
# ---------------------------------------------------------------------------

def blockwise_quantize(x):
    """Quantize an arbitrary tensor to (int8 payload, f32 per-block scales).

    The tensor's flattened length must be a multiple of QUANT_BLOCK (the
    model pads hidden dims accordingly; hidden_size % 64 == 0 always holds
    for BLOOM-family geometry).
    """
    flat = x.reshape(-1)
    assert flat.shape[0] % QUANT_BLOCK == 0, flat.shape
    blocks = flat.reshape(-1, QUANT_BLOCK)
    absmax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.where(absmax == 0.0, 1.0, absmax / 127.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale.reshape(-1).astype(jnp.float32)


def blockwise_dequantize(q, scales, shape):
    """Inverse of blockwise_quantize."""
    blocks = q.reshape(-1, QUANT_BLOCK).astype(jnp.float32)
    out = blocks * scales.reshape(-1, 1)
    return out.reshape(shape)


# ---------------------------------------------------------------------------
# LLM.int8() matmul with outlier decomposition
# ---------------------------------------------------------------------------

def int8_matmul_prepare_weights(w, outlier_mask):
    """Split + quantize a weight matrix for the int8 path.

    w: [K, N] float32; outlier_mask: [K] bool (True -> row kept in f32;
    outlier feature dims index the *contraction* axis).
    Returns (w_q int8 [K, N], w_scale f32 [N], w_out f32 [K, N] zero-masked).
    Regular rows are quantized column-wise (per output feature) as in
    vector-wise quantization; outlier rows are zeroed in the int8 copy and
    kept exactly in w_out.
    """
    reg = jnp.where(outlier_mask[:, None], 0.0, w)
    absmax = jnp.max(jnp.abs(reg), axis=0)
    w_scale = jnp.where(absmax == 0.0, 1.0, absmax / 127.0)
    w_q = jnp.clip(jnp.round(reg / w_scale[None, :]), -127, 127).astype(jnp.int8)
    w_out = jnp.where(outlier_mask[:, None], w, 0.0)
    return w_q, w_scale.astype(jnp.float32), w_out


def int8_matmul(x, w_q, w_scale, w_out, outlier_mask):
    """Mixed-precision matmul: int8 regular part + f32 outlier part.

    x: [M, K] f32. Returns [M, N] f32.
    The int8 path quantizes x row-wise (per example) with absmax over the
    regular columns only, multiplies in int32, and dequantizes with the
    product of row and column scales. Outlier columns go through a plain
    f32 matmul against w_out.
    """
    x_reg = jnp.where(outlier_mask[None, :], 0.0, x)
    x_absmax = jnp.max(jnp.abs(x_reg), axis=1)
    x_scale = jnp.where(x_absmax == 0.0, 1.0, x_absmax / 127.0)
    x_q = jnp.clip(jnp.round(x_reg / x_scale[:, None]), -127, 127).astype(jnp.int8)

    acc = jnp.matmul(
        x_q.astype(jnp.int32), w_q.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    reg_part = acc.astype(jnp.float32) * x_scale[:, None] * w_scale[None, :]

    x_out = jnp.where(outlier_mask[None, :], x, 0.0)
    out_part = jnp.matmul(x_out, w_out)
    return reg_part + out_part


def detect_outlier_columns(x, threshold=OUTLIER_THRESHOLD):
    """Feature dims whose activation absmax exceeds the threshold."""
    return jnp.max(jnp.abs(x), axis=tuple(range(x.ndim - 1))) > threshold


# ---------------------------------------------------------------------------
# Decode attention with ALiBi (BLOOM-style), single-token query
# ---------------------------------------------------------------------------

def alibi_slopes(n_heads):
    """ALiBi head slopes, as in the BLOOM / Press et al. (2022) recipe.

    For n_heads a power of two: slopes are 2^(-8i/n) for i in 1..n.
    (BLOOM-mini always uses power-of-two head counts.)
    """
    import math
    assert n_heads & (n_heads - 1) == 0, "power-of-two heads only"
    start = 2.0 ** (-(2.0 ** -(math.log2(n_heads) - 3)))
    return jnp.array([start * (start ** i) for i in range(n_heads)],
                     dtype=jnp.float32)


def decode_attention(q, k_cache, v_cache, cache_len, n_heads=None):
    """Single-token attention over a KV cache with ALiBi bias.

    q:        [B, H, D]        query for the current position
    k_cache:  [B, H, S, D]     keys, only [.., :cache_len, ..] valid
    v_cache:  [B, H, S, D]
    cache_len: scalar int32, number of valid cache positions (includes the
               current token, already written at position cache_len-1)
    Returns [B, H, D].

    ALiBi adds slope_h * -(distance) to the logits, distance measured from
    the current position (cache_len-1) back to each key position.
    """
    b, h, s, d = k_cache.shape
    scale = 1.0 / jnp.sqrt(jnp.array(d, jnp.float32))
    logits = jnp.einsum("bhd,bhsd->bhs", q, k_cache) * scale

    pos = jnp.arange(s)
    dist = (cache_len - 1) - pos  # 0 for current token, grows backwards
    slopes = alibi_slopes(h)  # [H]
    bias = -slopes[None, :, None] * dist[None, None, :].astype(jnp.float32)
    logits = logits + bias

    mask = pos[None, None, :] < cache_len
    logits = jnp.where(mask, logits, -1e30)
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("bhs,bhsd->bhd", probs, v_cache)
