"""Pallas kernel: fused single-token decode attention with ALiBi.

The inference hot path of a Petals server (§2.1) is one-token-at-a-time
generation against a per-session KV cache. Each decode step reads the
whole cache once; on a real accelerator this is bandwidth-bound, so the
kernel is organized as a single pass over the sequence axis in VMEM-sized
tiles with an online (streaming) softmax — the same structure Flash-
style decoders use, adapted to TPU:

  grid (H, S/BS); each step loads k/v tiles [B, BS, D] into VMEM,
  computes logits + ALiBi bias on the VPU, and folds them into running
  (max, sum, weighted-V) accumulators carried in scratch refs. The whole
  BATCH is processed inside one grid instance (§Perf iteration 2: a
  (B, H, S/BS) grid serialized over batch under interpret=True and on
  TPU wastes VPU lanes; batching the block keeps lanes full and makes
  throughput grow with B, which is what Table 2 measures).

ALiBi (BLOOM's positional scheme): logits[h, s] += -slope_h * (cur - s),
masked to s < cache_len. cache_len arrives as a tiny i32 tensor because
AOT artifacts use static shapes.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Max sequence tile per grid step. B x 128 keys x 64 head-dim x 4 B =
# 32 KiB per k tile per example — double-buffers in VMEM up to B=32.
BS = 128

NEG_INF = -1e30


def _alibi_slopes(n_heads):
    assert n_heads & (n_heads - 1) == 0, "power-of-two heads only"
    start = 2.0 ** (-(2.0 ** -(math.log2(n_heads) - 3)))
    return jnp.array([start * (start ** i) for i in range(n_heads)],
                     dtype=jnp.float32)


def _seq_tile(s):
    bs = min(BS, s)
    assert s % bs == 0, (s, bs)
    return bs


def _make_decode_kernel(bs):
    """Build the kernel body for a given sequence-tile size."""

    def _decode_kernel(len_ref, slope_ref, q_ref, k_ref, v_ref,
                       o_ref, m_ref, l_ref, acc_ref):
        """One (head, seq-tile) step of streaming-softmax decode over the
        full batch. Accumulators fold across the seq-tile axis (innermost
        grid dim); the final tile writes the normalized output."""
        s_idx = pl.program_id(1)
        n_s = pl.num_programs(1)

        @pl.when(s_idx == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        q = q_ref[:, 0, :]                       # [B, D]
        k = k_ref[:, 0, :, :]                    # [B, bs, D]
        v = v_ref[:, 0, :, :]                    # [B, bs, D]
        cache_len = len_ref[0]
        slope = slope_ref[0]

        d = q.shape[-1]
        scale = 1.0 / jnp.sqrt(jnp.float32(d))
        # broadcast-multiply + axis sum, NOT einsum: a batched dot-general
        # ("bd,bsd->bs") vectorizes across rows on CPU XLA and is not
        # batch-invariant — row r of a width-B call would differ in the
        # last ulp from the same row at width 1, breaking the fused-vs-
        # serial bitwise contract continuous batching is pinned to
        logits = jnp.sum(q[:, None, :] * k, axis=-1) * scale   # [B, bs]

        pos = s_idx * bs + jax.lax.iota(jnp.int32, bs)
        dist = (cache_len - 1) - pos
        logits = logits - slope * dist.astype(jnp.float32)[None, :]
        logits = jnp.where((pos < cache_len)[None, :], logits, NEG_INF)

        # Online softmax fold (per batch row).
        m_prev = m_ref[...]                                # [B]
        m_cur = jnp.maximum(m_prev, jnp.max(logits, axis=1))
        alpha = jnp.exp(m_prev - m_cur)                    # [B]
        p = jnp.exp(logits - m_cur[:, None])               # [B, bs]
        l_cur = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_cur = acc_ref[...] * alpha[:, None] + jnp.sum(p[:, :, None] * v, axis=1)

        m_ref[...] = m_cur
        l_ref[...] = l_cur
        acc_ref[...] = acc_cur

        @pl.when(s_idx == n_s - 1)
        def _finish():
            o_ref[:, 0, :] = acc_ref[...] / l_ref[...][:, None]

    return _decode_kernel


def _make_ragged_decode_kernel(bs):
    """Kernel body for per-row cache lengths (ragged continuous
    batching): `len_ref` holds one valid-position count PER ROW, so a
    fused batch can mix sessions at different decode depths. Per-row
    arithmetic is identical to [`_make_decode_kernel`]'s — same einsum,
    same ALiBi bias, same online-softmax fold — only the mask and the
    distance term broadcast over a `[B]` length vector instead of a
    scalar, which keeps each row bitwise equal to running it alone
    (asserted in python/tests/test_ragged_decode.py)."""

    def _ragged_kernel(len_ref, slope_ref, q_ref, k_ref, v_ref,
                       o_ref, m_ref, l_ref, acc_ref):
        s_idx = pl.program_id(1)
        n_s = pl.num_programs(1)

        @pl.when(s_idx == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        q = q_ref[:, 0, :]                       # [B, D]
        k = k_ref[:, 0, :, :]                    # [B, bs, D]
        v = v_ref[:, 0, :, :]                    # [B, bs, D]
        lens = len_ref[...]                      # [B]
        slope = slope_ref[0]

        d = q.shape[-1]
        scale = 1.0 / jnp.sqrt(jnp.float32(d))
        # batch-invariant formulation — see the uniform kernel's comment
        logits = jnp.sum(q[:, None, :] * k, axis=-1) * scale   # [B, bs]

        pos = s_idx * bs + jax.lax.iota(jnp.int32, bs)
        dist = (lens[:, None] - 1) - pos[None, :]          # [B, bs]
        logits = logits - slope * dist.astype(jnp.float32)
        # rows past their own length see NEG_INF — a fully masked tile
        # (a short row in a deep batch) folds in exp(NEG_INF - m) == 0,
        # so padding stays causally invisible per row
        logits = jnp.where(pos[None, :] < lens[:, None], logits, NEG_INF)

        m_prev = m_ref[...]                                # [B]
        m_cur = jnp.maximum(m_prev, jnp.max(logits, axis=1))
        alpha = jnp.exp(m_prev - m_cur)                    # [B]
        p = jnp.exp(logits - m_cur[:, None])               # [B, bs]
        l_cur = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_cur = acc_ref[...] * alpha[:, None] + jnp.sum(p[:, :, None] * v, axis=1)

        m_ref[...] = m_cur
        l_ref[...] = l_cur
        acc_ref[...] = acc_cur

        @pl.when(s_idx == n_s - 1)
        def _finish():
            o_ref[:, 0, :] = acc_ref[...] / l_ref[...][:, None]

    return _ragged_kernel


@functools.partial(jax.jit, static_argnames=())
def ragged_decode_attention(q, k_cache, v_cache, cache_lens):
    """Per-row ALiBi attention over the KV cache — the ragged-batching
    twin of [`decode_attention`].

    q: [B, H, D];  k_cache, v_cache: [B, H, S, D];
    cache_lens: i32[B] — valid positions PER ROW (each row's current
    token already written at cache_lens[b]-1). Returns [B, H, D] f32.
    """
    b, h, s, d = k_cache.shape
    bs = _seq_tile(s)
    len_arr = jnp.asarray(cache_lens, jnp.int32).reshape(b)
    slopes = _alibi_slopes(h)

    return pl.pallas_call(
        _make_ragged_decode_kernel(bs),
        grid=(h, s // bs),
        in_specs=[
            pl.BlockSpec((b,), lambda j, t: (0,)),
            pl.BlockSpec((1,), lambda j, t: (j,)),
            pl.BlockSpec((b, 1, d), lambda j, t: (0, j, 0)),
            pl.BlockSpec((b, 1, bs, d), lambda j, t: (0, j, t, 0)),
            pl.BlockSpec((b, 1, bs, d), lambda j, t: (0, j, t, 0)),
        ],
        out_specs=pl.BlockSpec((b, 1, d), lambda j, t: (0, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((b,), jnp.float32),   # running max
            pltpu.VMEM((b,), jnp.float32),   # running sum
            pltpu.VMEM((b, d), jnp.float32), # weighted V accumulator
        ],
        interpret=True,
    )(len_arr, slopes, q, k_cache, v_cache)


@functools.partial(jax.jit, static_argnames=())
def decode_attention(q, k_cache, v_cache, cache_len):
    """Single-token ALiBi attention over the KV cache.

    q: [B, H, D];  k_cache, v_cache: [B, H, S, D];
    cache_len: i32[] or i32[1] — number of valid positions (current token
    already written at cache_len-1). Returns [B, H, D] f32.
    """
    b, h, s, d = k_cache.shape
    bs = _seq_tile(s)
    len_arr = jnp.asarray(cache_len, jnp.int32).reshape(1)
    slopes = _alibi_slopes(h)

    return pl.pallas_call(
        _make_decode_kernel(bs),
        grid=(h, s // bs),
        in_specs=[
            pl.BlockSpec((1,), lambda j, t: (0,)),
            pl.BlockSpec((1,), lambda j, t: (j,)),
            pl.BlockSpec((b, 1, d), lambda j, t: (0, j, 0)),
            pl.BlockSpec((b, 1, bs, d), lambda j, t: (0, j, t, 0)),
            pl.BlockSpec((b, 1, bs, d), lambda j, t: (0, j, t, 0)),
        ],
        out_specs=pl.BlockSpec((b, 1, d), lambda j, t: (0, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((b,), jnp.float32),   # running max
            pltpu.VMEM((b,), jnp.float32),   # running sum
            pltpu.VMEM((b, d), jnp.float32), # weighted V accumulator
        ],
        interpret=True,
    )(len_arr, slopes, q, k_cache, v_cache)
