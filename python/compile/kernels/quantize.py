"""Pallas kernel: dynamic blockwise int8 quantization (comm compression).

Petals §3.1 "Compressing communication buffers": hidden states exchanged
between pipeline stages are quantized with dynamic blockwise quantization
(Dettmers et al., 2022b), halving bandwidth with no noticeable quality
effect. This file implements the quantize and dequantize halves as Pallas
kernels so they lower into the same HLO as the surrounding model code and
run on-device right before/after the network boundary.

Layout (must match kernels/ref.py and rust/src/quant/):
  payload: int8[n]           (n % 64 == 0)
  scales:  f32[n / 64]       absmax-of-block / 127

TPU mapping: a pure VPU kernel — per-block absmax is a lane reduction over
a (TILE_BLOCKS, 64) VMEM tile; no MXU involvement. The tile size is chosen
so one (in, out, scales) triple stays far under VMEM (~16 MB): 512 blocks
x 64 elems x 4 B = 128 KiB in, 32 KiB out, 2 KiB scales.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import QUANT_BLOCK

# Blocks of QUANT_BLOCK elements processed by one grid step.
TILE_BLOCKS = 512


def _quantize_kernel(x_ref, q_ref, s_ref):
    """One grid step: quantize TILE_BLOCKS rows of QUANT_BLOCK elements."""
    x = x_ref[...]  # [TILE_BLOCKS, QUANT_BLOCK] f32
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(absmax == 0.0, 1.0, absmax / 127.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale[:, 0].astype(jnp.float32)


def _dequantize_kernel(q_ref, s_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)  # [TILE_BLOCKS, QUANT_BLOCK]
    o_ref[...] = q * s_ref[...][:, None]


def _pad_blocks(n_blocks):
    """Grid-pad the block count to a multiple of TILE_BLOCKS."""
    return (n_blocks + TILE_BLOCKS - 1) // TILE_BLOCKS * TILE_BLOCKS


@functools.partial(jax.jit, static_argnames=())
def blockwise_quantize(x):
    """Quantize a tensor to (int8 payload, f32 block scales) via Pallas.

    x: any shape with size % QUANT_BLOCK == 0. Returns (q[n] int8,
    scales[n/64] f32) with the flattened layout of ref.blockwise_quantize.
    """
    flat = x.reshape(-1)
    n = flat.shape[0]
    assert n % QUANT_BLOCK == 0, n
    n_blocks = n // QUANT_BLOCK
    padded = _pad_blocks(n_blocks)
    rows = jnp.zeros((padded, QUANT_BLOCK), flat.dtype).at[:n_blocks].set(
        flat.reshape(n_blocks, QUANT_BLOCK))

    q, s = pl.pallas_call(
        _quantize_kernel,
        grid=(padded // TILE_BLOCKS,),
        in_specs=[pl.BlockSpec((TILE_BLOCKS, QUANT_BLOCK), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((TILE_BLOCKS, QUANT_BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((TILE_BLOCKS,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((padded, QUANT_BLOCK), jnp.int8),
            jax.ShapeDtypeStruct((padded,), jnp.float32),
        ],
        interpret=True,
    )(rows)
    return q[:n_blocks].reshape(-1), s[:n_blocks]


def blockwise_dequantize(q, scales, shape):
    """Inverse of blockwise_quantize; returns f32 tensor of `shape`."""
    n_blocks = scales.shape[0]
    padded = _pad_blocks(n_blocks)
    q_rows = jnp.zeros((padded, QUANT_BLOCK), jnp.int8).at[:n_blocks].set(
        q.reshape(n_blocks, QUANT_BLOCK))
    s_rows = jnp.zeros((padded,), jnp.float32).at[:n_blocks].set(scales)

    out = pl.pallas_call(
        _dequantize_kernel,
        grid=(padded // TILE_BLOCKS,),
        in_specs=[
            pl.BlockSpec((TILE_BLOCKS, QUANT_BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((TILE_BLOCKS,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((TILE_BLOCKS, QUANT_BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, QUANT_BLOCK), jnp.float32),
        interpret=True,
    )(q_rows, s_rows)
    return out[:n_blocks].reshape(shape)
