"""Pallas kernel: LLM.int8() matmul with outlier decomposition.

Petals §3.1 "Compressing model weights": weights are stored in 8-bit using
mixed int8/f16 matrix decomposition (Dettmers et al., 2022a). ~0.1% of
feature dimensions carry activation outliers and stay in 16-bit; the other
99.9% multiply in int8. This halves server memory, which halves the number
of pipeline stages (44 -> 22 for BLOOM-176B) and therefore latency.

Hardware adaptation (paper: CUDA tensor cores + cuBLASLt int8): on TPU the
regular path is an MXU int8 x int8 -> int32 matmul and the outlier path a
small f32 (stands in for bf16) matmul, both fed from VMEM tiles:

  grid (M/BM, N/BN); each step streams the full-K strips
      x_q   [BM, K] int8     w_q  [K, BN] int8      (MXU, int32 acc)
      x_out [BM, K] f32      w_out[K, BN] f32       (outlier GEMM)
  and combines   acc * x_scale[:,None] * w_scale[None,:] + outlier.

With K = hidden = 512..4096, the int8 strips are K*BM and K*BN bytes —
e.g. BM=BN=128, K=4096: 512 KiB + 512 KiB int8 + 2x 2 MiB f32 outlier
strips, comfortably inside 16 MiB VMEM with double buffering. The outlier
strip is structurally sparse (only outlier rows are nonzero); a production
TPU kernel would gather the ~0.1% rows — here we keep the dense form for
interpret-mode clarity and account for that in the §Perf estimate.

Row-wise activation quantization (vector-wise, per the paper) happens in a
separate single-pass VPU kernel because each row's absmax needs the whole
row before any tile of the GEMM can be dequantized.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# GEMM tile sizes (MXU-native 128x128 output tiles at full batch).
BM = 128
BN = 128
# Row-quantization tile.
BQ = 128


def _adaptive_bm(m, full):
    """Row-tile size for small-M GEMMs (single-token decode).

    Padding M=1 up to the MXU-native 128 wastes 128x multiplier work —
    harmless on a systolic array that is latency-bound at M<8 anyway,
    but catastrophic under interpret=True where every padded row costs
    real CPU work. Use the smallest sublane-aligned (multiple-of-8) tile
    covering M, capped at the native size. On real TPU the MXU consumes
    (8,128) sublane tiles, so small BM remains hardware-friendly.
    """
    if m >= full:
        return full
    return max(8, -(-m // 8) * 8)


def _row_quant_kernel(x_ref, mask_ref, q_ref, s_ref):
    """Quantize BQ rows of x, masking outlier columns out of the int8 path.

    mask is f32 (1.0 = outlier column, 0.0 = regular) — kept float so the
    same artifact format serves HLO (no i1 tensors across entry points).
    """
    x = x_ref[...]                      # [BQ, K] f32
    keep = 1.0 - mask_ref[...]          # [K]
    x_reg = x * keep[None, :]
    absmax = jnp.max(jnp.abs(x_reg), axis=1, keepdims=True)
    scale = jnp.where(absmax == 0.0, 1.0, absmax / 127.0)
    q_ref[...] = jnp.clip(jnp.round(x_reg / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = scale[:, 0].astype(jnp.float32)


def _gemm_kernel(x_q_ref, x_s_ref, x_out_ref, w_q_ref, w_s_ref, w_out_ref,
                 o_ref):
    """One (BM, BN) output tile: int8 MXU GEMM + f32 outlier GEMM."""
    x_q = x_q_ref[...].astype(jnp.int32)     # [BM, K]
    w_q = w_q_ref[...].astype(jnp.int32)     # [K, BN]
    acc = jax.lax.dot(x_q, w_q, preferred_element_type=jnp.int32)
    reg = acc.astype(jnp.float32) * x_s_ref[...][:, None] * w_s_ref[...][None, :]
    out = jax.lax.dot(x_out_ref[...], w_out_ref[...],
                      preferred_element_type=jnp.float32)
    o_ref[...] = reg + out


def _pad_to(x, mult, axis):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def row_quantize(x, outlier_mask_f32):
    """Vector-wise int8 activation quantization (outlier columns excluded).

    x: [M, K] f32; outlier_mask_f32: [K] f32 in {0,1}.
    Returns (x_q int8 [M, K], x_scale f32 [M]).
    """
    m, k = x.shape
    bq = _adaptive_bm(m, BQ)
    xp = _pad_to(x, bq, 0)
    mp = xp.shape[0]
    q, s = pl.pallas_call(
        _row_quant_kernel,
        grid=(mp // bq,),
        in_specs=[
            pl.BlockSpec((bq, k), lambda i: (i, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i: (i, 0)),
            pl.BlockSpec((bq,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, k), jnp.int8),
            jax.ShapeDtypeStruct((mp,), jnp.float32),
        ],
        interpret=True,
    )(xp, outlier_mask_f32)
    return q[:m], s[:m]


def int8_matmul(x, w_q, w_scale, w_out, outlier_mask_f32):
    """Mixed int8/f32 matmul with outlier decomposition, Pallas-tiled.

    x: [M, K] f32;  w_q: [K, N] int8;  w_scale: [N] f32;
    w_out: [K, N] f32 (zero except outlier rows);  outlier_mask_f32: [K].
    Returns [M, N] f32. Matches ref.int8_matmul.
    """
    m, k = x.shape
    n = w_q.shape[1]

    x_q, x_s = row_quantize(x, outlier_mask_f32)
    x_out = x * outlier_mask_f32[None, :]

    bm = _adaptive_bm(m, BM)
    x_q = _pad_to(x_q, bm, 0)
    x_s = _pad_to(x_s, bm, 0)
    x_out = _pad_to(x_out, bm, 0)
    w_qp = _pad_to(w_q, BN, 1)
    w_sp = _pad_to(w_scale, BN, 0)
    w_op = _pad_to(w_out, BN, 1)
    mp, np_ = x_q.shape[0], w_qp.shape[1]

    out = pl.pallas_call(
        _gemm_kernel,
        grid=(mp // bm, np_ // BN),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, BN), lambda i, j: (0, j)),
            pl.BlockSpec((BN,), lambda i, j: (j,)),
            pl.BlockSpec((k, BN), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, BN), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(x_q, x_s, x_out, w_qp, w_sp, w_op)
    return out[:m, :n]
