"""AOT compile path: lower every entry point to HLO text + export weights.

Run once via `make artifacts` (no-op when inputs are unchanged); Python
never appears on the request path. Outputs under artifacts/:

  <entry>.hlo.txt        HLO text per entry point (NOT serialized proto:
                         the xla crate's xla_extension 0.5.1 rejects
                         jax>=0.5 64-bit instruction ids; the text parser
                         reassigns ids — see /opt/xla-example/README.md)
  manifest.json          geometry + per-entry arg/output shapes + weight
                         tensor index (shapes, dtypes, files)
  weights/...            f32 little-endian tensor files
  weights_int8/...       LLM.int8() packs (w_q/w_scale/w_out/mask)
  golden/...             input/output vectors for the rust numerics tests

Entry-point naming: <fn>_b{B}[_s{S}|_c{C}] — static shapes per artifact;
the rust runtime picks the artifact matching the request shape.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dt(x):
    return {"float32": "f32", "int8": "i8", "int32": "i32"}[str(x.dtype)]


def _arg_meta(args):
    return [{"shape": list(a.shape), "dtype": _dt(a)} for a in args]


def save_tensor(root, rel, arr):
    path = os.path.join(root, rel)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    np.asarray(arr).tofile(path)
    return {"file": rel, "shape": list(arr.shape), "dtype": _dt(arr)}


class Emitter:
    def __init__(self, cfg, out_dir):
        self.cfg = cfg
        self.out = out_dir
        self.entries = {}

    def emit(self, name, fn, arg_specs, golden_args=None):
        """Lower fn(*arg_specs) to <name>.hlo.txt; optionally run it on
        golden_args and save in/out vectors for the rust numerics test."""
        lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(self.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *arg_specs)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        self.entries[name] = {
            "file": f"{name}.hlo.txt",
            "args": _arg_meta(arg_specs),
            "outputs": _arg_meta(outs),
        }
        if golden_args is not None:
            res = fn(*golden_args)
            if not isinstance(res, (tuple, list)):
                res = (res,)
            g = {"inputs": [], "outputs": []}
            for i, a in enumerate(golden_args):
                g["inputs"].append(
                    save_tensor(self.out, f"golden/{name}/in{i}.bin", a))
            for i, r in enumerate(res):
                g["outputs"].append(
                    save_tensor(self.out, f"golden/{name}/out{i}.bin", r))
            self.entries[name]["golden"] = g
        print(f"  emitted {name}: {len(text)} chars")


def export_weights(cfg, params, masks, out_dir):
    """Write f32 + int8 weight tensors; return the manifest index."""
    idx = {"embedding": save_tensor(out_dir, "weights/embedding.bin",
                                    params["embedding"])}
    for n in ("ln_emb_g", "ln_emb_b", "ln_f_g", "ln_f_b"):
        idx[n] = save_tensor(out_dir, f"weights/{n}.bin", params[n])
    blocks = []
    for i, bp in enumerate(params["blocks"]):
        entry = {}
        for n in M.BLOCK_PARAM_NAMES:
            entry[n] = save_tensor(out_dir, f"weights/block{i}/{n}.bin", bp[n])
        blocks.append(entry)
    idx["blocks"] = blocks

    blocks8 = []
    for i, (bp, mask) in enumerate(zip(params["blocks"], masks)):
        p8 = M.prepare_int8_params(bp, mask)
        entry = {}
        for n in M.BLOCK_PARAM_NAMES:
            if n in M.INT8_MATMULS:
                w_q, w_s, w_o, m = p8[n]
                entry[n] = {
                    "w_q": save_tensor(out_dir, f"weights_int8/block{i}/{n}.w_q.bin", w_q),
                    "w_scale": save_tensor(out_dir, f"weights_int8/block{i}/{n}.w_scale.bin", w_s),
                    "w_out": save_tensor(out_dir, f"weights_int8/block{i}/{n}.w_out.bin", w_o),
                    "mask": save_tensor(out_dir, f"weights_int8/block{i}/{n}.mask.bin", m),
                }
            else:
                entry[n] = {"ref": f"weights/block{i}/{n}.bin"}
        blocks8.append(entry)
    idx["blocks_int8"] = blocks8
    return idx


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--hidden", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--heads", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--decode-batches", type=int, nargs="+",
                    default=[1, 8, 32])
    ap.add_argument("--prefill-shapes", type=str, nargs="+",
                    default=["1x128", "8x128", "32x128", "4x64"],
                    help="BxS prefill entry points")
    args = ap.parse_args()

    cfg = M.ModelConfig(hidden=args.hidden, n_layers=args.layers,
                        n_heads=args.heads, vocab=args.vocab,
                        max_seq=args.max_seq)
    os.makedirs(args.out, exist_ok=True)
    em = Emitter(cfg, args.out)

    print(f"BLOOM-mini: {cfg} ({cfg.params_per_block() * cfg.n_layers + cfg.vocab * cfg.hidden:,} params)")
    params = M.init_model_params(cfg, seed=args.seed)
    key = jax.random.PRNGKey(1234)
    calib_ids = jax.random.randint(key, (2, 64), 0, cfg.vocab)
    masks = M.calibrate_outlier_masks(cfg, params, calib_ids)
    weights_idx = export_weights(cfg, params, masks, args.out)

    h, hh, d, c, v = cfg.hidden, cfg.n_heads, cfg.head_dim, cfg.max_seq, cfg.vocab
    bp0 = params["blocks"][0]
    flat0 = [bp0[n] for n in M.BLOCK_PARAM_NAMES]
    flat0_8 = M.flatten_int8_params(M.prepare_int8_params(bp0, masks[0]))
    pshapes = {n: p.shape for n, p in zip(M.BLOCK_PARAM_NAMES, flat0)}
    block_specs = [spec(pshapes[n]) for n in M.BLOCK_PARAM_NAMES]
    block8_specs = [spec(t.shape, t.dtype) for t in flat0_8]

    gkey = jax.random.PRNGKey(99)
    prefills = [tuple(map(int, s.split("x"))) for s in args.prefill_shapes]

    # --- embed + lm_head, all batch sizes used anywhere -------------------
    embed_shapes = sorted({(b, s) for b, s in prefills} |
                          {(b, 1) for b in args.decode_batches})
    for b, s in embed_shapes:
        g_ids = jax.random.randint(gkey, (b, s), 0, v)
        em.emit(f"embed_b{b}_s{s}",
                lambda ids, e, g, bb: M.embed_fn(cfg, ids, e, g, bb),
                [spec((b, s), jnp.int32), spec((v, h)), spec((h,)), spec((h,))],
                golden_args=[g_ids, params["embedding"],
                             params["ln_emb_g"], params["ln_emb_b"]])
    for b in sorted({b for b, _ in embed_shapes}):
        g_h = jax.random.normal(gkey, (b, h))
        em.emit(f"lm_head_b{b}",
                lambda x, g, bb, e: M.lm_head_fn(cfg, x, g, bb, e),
                [spec((b, h)), spec((h,)), spec((h,)), spec((v, h))],
                golden_args=[g_h, params["ln_f_g"], params["ln_f_b"],
                             params["embedding"]])

    # --- block prefill (f32 + int8) ---------------------------------------
    for b, s in prefills:
        g_h = jax.random.normal(gkey, (b, s, h)) * 0.5
        em.emit(f"block_prefill_b{b}_s{s}",
                lambda x, *p: M.block_prefill_fn(cfg, x, *p),
                [spec((b, s, h))] + block_specs,
                golden_args=([g_h] + flat0) if b <= 4 else None)
    # int8 prefill for every decode batch size (servers hosting int8
    # spans must prefill sessions at any supported batch)
    for b in args.decode_batches:
        s = prefills[0][1]
        g_h = jax.random.normal(gkey, (b, s, h)) * 0.5
        em.emit(f"block_prefill_int8_b{b}_s{s}",
                lambda x, *p: M.block_prefill_int8_fn(cfg, x, *p),
                [spec((b, s, h))] + block8_specs,
                golden_args=([g_h] + list(flat0_8)) if b == 1 else None)

    # --- block decode (f32 + int8) ----------------------------------------
    for b in args.decode_batches:
        g_h = jax.random.normal(gkey, (b, 1, h)) * 0.5
        g_k = jax.random.normal(gkey, (b, hh, c, d)) * 0.5
        g_v = jax.random.normal(gkey, (b, hh, c, d)) * 0.5
        g_len = jnp.array([7], jnp.int32)
        dec_specs = [spec((b, 1, h)), spec((b, hh, c, d)), spec((b, hh, c, d)),
                     spec((1,), jnp.int32)]
        em.emit(f"block_decode_b{b}_c{c}",
                lambda x, kc, vc, ln, *p: M.block_decode_fn(cfg, x, kc, vc, ln, *p),
                dec_specs + block_specs,
                golden_args=([g_h, g_k, g_v, g_len] + flat0) if b == 1 else None)
        em.emit(f"block_decode_int8_b{b}_c{c}",
                lambda x, kc, vc, ln, *p: M.block_decode_int8_fn(cfg, x, kc, vc, ln, *p),
                dec_specs + block8_specs,
                golden_args=([g_h, g_k, g_v, g_len] + list(flat0_8)) if b == 1 else None)
        # ragged decode: one cache length PER ROW, so the server can fuse
        # sessions at different decode depths into one call (cross-row
        # equivalence to the uniform entry is pinned by
        # python/tests/test_ragged_decode.py)
        g_lens = jnp.array([7 + 3 * i for i in range(b)], jnp.int32)
        rag_specs = [spec((b, 1, h)), spec((b, hh, c, d)), spec((b, hh, c, d)),
                     spec((b,), jnp.int32)]
        em.emit(f"block_decode_ragged_b{b}_c{c}",
                lambda x, kc, vc, ln, *p: M.block_decode_ragged_fn(cfg, x, kc, vc, ln, *p),
                rag_specs + block_specs,
                golden_args=([g_h, g_k, g_v, g_lens] + flat0) if b == 1 else None)
        em.emit(f"block_decode_ragged_int8_b{b}_c{c}",
                lambda x, kc, vc, ln, *p: M.block_decode_ragged_int8_fn(cfg, x, kc, vc, ln, *p),
                rag_specs + block8_specs,
                golden_args=([g_h, g_k, g_v, g_lens] + list(flat0_8)) if b == 1 else None)

    # --- backward (fine-tuning) --------------------------------------------
    fb, fs = prefills[-1]  # finetune shape (default 4x64)
    g_h = jax.random.normal(gkey, (fb, fs, h)) * 0.5
    g_g = jax.random.normal(gkey, (fb, fs, h)) * 0.1
    em.emit(f"block_bwd_b{fb}_s{fs}",
            lambda x, gy, *p: M.block_bwd_fn(cfg, x, gy, *p),
            [spec((fb, fs, h)), spec((fb, fs, h))] + block_specs,
            golden_args=[g_h, g_g] + flat0)

    # --- comm compression (pallas quant on the wire format) ----------------
    for b, s in [(1, 1), (1, 128)]:
        n = b * s * h
        g_x = jax.random.normal(gkey, (b, s, h)) * 2.0
        em.emit(f"quantize_hidden_b{b}_s{s}",
                lambda x: M.quantize_hidden_fn(cfg, x),
                [spec((b, s, h))], golden_args=[g_x])
        g_q, g_s = M.quantize_hidden_fn(cfg, g_x)
        em.emit(f"dequantize_hidden_b{b}_s{s}",
                lambda q, sc: M.dequantize_hidden_fn(cfg, q, sc, (b, s, h)),
                [spec((n,), jnp.int8), spec((n // 64,), jnp.float32)],
                golden_args=[g_q, g_s])

    # --- whole-model golden generation (end-to-end rust check) -------------
    gen_prefix = jax.random.randint(jax.random.PRNGKey(5), (1, 8), 0, v)
    gen_out = M.generate_greedy(cfg, params, gen_prefix, 8)
    golden_gen = {
        "prefix": save_tensor(args.out, "golden/generate/prefix.bin",
                              gen_prefix.astype(np.int32)),
        "tokens": save_tensor(args.out, "golden/generate/tokens.bin",
                              gen_out.astype(np.int32)),
    }
    logits = M.forward_full(cfg, params, gen_prefix)
    golden_gen["logits_last"] = save_tensor(
        args.out, "golden/generate/logits_last.bin", logits[:, -1])

    manifest = {
        "config": {
            "hidden": cfg.hidden, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "head_dim": cfg.head_dim,
            "vocab": cfg.vocab, "max_seq": cfg.max_seq, "ffn": cfg.ffn,
            "block_bytes_f16": cfg.block_bytes("f16"),
            "block_bytes_int8": cfg.block_bytes("int8"),
            "params_per_block": cfg.params_per_block(),
        },
        "entries": em.entries,
        "weights": weights_idx,
        "golden_generate": golden_gen,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(em.entries)} entries -> {args.out}/manifest.json")


if __name__ == "__main__":
    main()
