"""Layer 2: BLOOM-architecture Transformer in JAX (build-time only).

This defines "BLOOM-mini": the exact BLOOM block structure (pre-LN,
ALiBi attention, GELU MLP, tied embeddings with a word-embedding
layernorm) at a configurable small geometry, with synthetic weights.
Petals' claims are about the *serving system*; the substitution of
synthetic weights for the 350 GB BLOOM-176B checkpoint is recorded in
DESIGN.md §Substitutions.

Every public `*_fn` here is an AOT entry point lowered by aot.py to
artifacts/<name>.hlo.txt and executed from the Rust runtime
(rust/src/runtime/). Entry points take flat positional tensor arguments
(no pytrees) so the Rust side can feed PJRT literals directly.

Two weight formats:
  f16 path  — plain f32 tensors (stands in for the paper's 16-bit path;
              CPU PJRT computes in f32 either way, the reproduced
              quantity is the int8-vs-16bit *delta*).
  int8 path — LLM.int8() decomposition per matmul: (w_q int8, w_scale
              f32[N], w_out f32 outlier rows, mask f32[K]) produced by
              `prepare_int8_params` from the same f32 weights, consumed
              by the Pallas kernel in kernels/int8_matmul.py.

Cache discipline (static shapes for AOT): the KV cache is a fixed
capacity-C buffer; `cache_len` i32[1] counts valid positions. A
`block_decode` call writes the new token's K/V at index cache_len and
attends over cache_len+1 positions via the Pallas decode kernel.
"""

import dataclasses
import math

import jax
import jax.numpy as jnp

from .kernels import attention as attn_kernel
from .kernels import int8_matmul as int8_kernel
from .kernels import quantize as quant_kernel
from .kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """BLOOM-mini geometry. hidden % 64 == 0 and power-of-two heads keep
    the quantization block layout and ALiBi slope recipe valid."""
    hidden: int = 512
    n_layers: int = 8
    n_heads: int = 8
    vocab: int = 2048
    max_seq: int = 256
    ffn_mult: int = 4

    @property
    def head_dim(self):
        return self.hidden // self.n_heads

    @property
    def ffn(self):
        return self.hidden * self.ffn_mult

    def params_per_block(self):
        h, f = self.hidden, self.ffn
        return 4 * h + 3 * h * h + 3 * h + h * h + h + 2 * h * f + f + h

    def block_bytes(self, precision):
        """Server-side memory accounting (capacity planning in rust).

        int8: matmul weights 1 B/param + ~0.4% outlier rows in f32 +
        per-output-column scales; vectors stay f32.
        """
        h, f = self.hidden, self.ffn
        matmul = h * 3 * h + h * h + h * f + f * h
        vectors = self.params_per_block() - matmul
        if precision == "int8":
            return int(matmul * 1.004 + vectors * 4 + (3 * h + h + f + h) * 4)
        return matmul * 4 + vectors * 4


# Fixed argument order for block parameters (the rust side mirrors this in
# rust/src/model/params.rs — keep in sync).
BLOCK_PARAM_NAMES = (
    "ln1_g", "ln1_b", "w_qkv", "b_qkv", "w_o", "b_o",
    "ln2_g", "ln2_b", "w_fc", "b_fc", "w_proj", "b_proj",
)

# Matmul weights that get the int8 treatment.
INT8_MATMULS = ("w_qkv", "w_o", "w_fc", "w_proj")


def block_param_shapes(cfg):
    h, f = cfg.hidden, cfg.ffn
    return {
        "ln1_g": (h,), "ln1_b": (h,),
        "w_qkv": (h, 3 * h), "b_qkv": (3 * h,),
        "w_o": (h, h), "b_o": (h,),
        "ln2_g": (h,), "ln2_b": (h,),
        "w_fc": (h, f), "b_fc": (f,),
        "w_proj": (f, h), "b_proj": (h,),
    }


def init_block_params(cfg, key):
    """BLOOM-style init: N(0, 0.02) matmuls (residual projections scaled
    by 1/sqrt(2L)), unit LN gains, zero biases."""
    shapes = block_param_shapes(cfg)
    keys = jax.random.split(key, len(INT8_MATMULS))
    params = {}
    std = 0.02
    for i, name in enumerate(INT8_MATMULS):
        s = std / math.sqrt(2 * cfg.n_layers) if name in ("w_o", "w_proj") else std
        params[name] = jax.random.normal(keys[i], shapes[name], jnp.float32) * s
    for name in BLOCK_PARAM_NAMES:
        if name in params:
            continue
        if name.endswith("_g"):
            params[name] = jnp.ones(shapes[name], jnp.float32)
        else:
            params[name] = jnp.zeros(shapes[name], jnp.float32)
    return params


def init_model_params(cfg, seed=0):
    """Full model: embedding (+LN) shared with the LM head, final LN, and
    per-block params."""
    root = jax.random.PRNGKey(seed)
    emb_key, *block_keys = jax.random.split(root, cfg.n_layers + 1)
    return {
        "embedding": jax.random.normal(
            emb_key, (cfg.vocab, cfg.hidden), jnp.float32) * 0.02,
        "ln_emb_g": jnp.ones((cfg.hidden,), jnp.float32),
        "ln_emb_b": jnp.zeros((cfg.hidden,), jnp.float32),
        "ln_f_g": jnp.ones((cfg.hidden,), jnp.float32),
        "ln_f_b": jnp.zeros((cfg.hidden,), jnp.float32),
        "blocks": [init_block_params(cfg, k) for k in block_keys],
    }


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _gelu(x):
    # BLOOM uses the tanh approximation.
    return 0.5 * x * (1.0 + jnp.tanh(
        math.sqrt(2.0 / math.pi) * (x + 0.044715 * x ** 3)))


def _split_heads(x, n_heads):
    b, s, h = x.shape
    d = h // n_heads
    return x.reshape(b, s, n_heads, d).transpose(0, 2, 1, 3)  # [B,Hh,S,D]


def _merge_heads(x):
    b, hh, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, hh * d)


def _prefill_attention(q, k, v, n_heads):
    """Causal ALiBi attention over a full prefix (plain jnp: prefill is
    compute-bound and XLA fuses it well; the Pallas kernel owns decode)."""
    b, hh, s, d = q.shape
    scale = 1.0 / math.sqrt(d)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    slopes = kref.alibi_slopes(n_heads)
    bias = -slopes[None, :, None, None] * (qpos - kpos)[None, None].astype(jnp.float32)
    logits = logits + bias
    logits = jnp.where((kpos <= qpos)[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


# ---------------------------------------------------------------------------
# Matmul dispatch: f32 path vs int8-decomposed path
# ---------------------------------------------------------------------------

def _mm(x2d, w):
    return x2d @ w


def _mm_int8(x2d, wpack):
    w_q, w_scale, w_out, mask = wpack
    return int8_kernel.int8_matmul(x2d, w_q, w_scale, w_out, mask)


def prepare_int8_params(block_params, outlier_masks):
    """Convert f32 block params to the int8 format.

    outlier_masks: dict matmul-name -> f32[K] in {0,1} from calibration
    (see `calibrate_outlier_masks`). Non-matmul params pass through.
    """
    out = {}
    for name in BLOCK_PARAM_NAMES:
        p = block_params[name]
        if name in INT8_MATMULS:
            mask = outlier_masks[name]
            w_q, w_scale, w_out = kref.int8_matmul_prepare_weights(
                p, mask.astype(bool))
            out[name] = (w_q, w_scale.astype(jnp.float32), w_out,
                         mask.astype(jnp.float32))
        else:
            out[name] = p
    return out


def _quantile_mask(x, quantile):
    amax = jnp.max(jnp.abs(x.reshape(-1, x.shape[-1])), axis=0)
    thresh = jnp.quantile(amax, quantile)
    return (amax > thresh).astype(jnp.float32)


def calibrate_outlier_masks(cfg, params, sample_ids, quantile=0.995):
    """Run the f32 model on calibration tokens and mark, per matmul, the
    top-(1-quantile) feature dims by activation absmax as outliers.

    Synthetic-weight activations rarely exceed the paper's absolute 6.0
    threshold, so a quantile rule exercises the same mechanism (~0.5% of
    dims stay in 16-bit, vs the paper's ~0.1%).
    """
    h = embed_fn(cfg, sample_ids, params["embedding"],
                 params["ln_emb_g"], params["ln_emb_b"])
    masks_per_block = []
    for bp in params["blocks"]:
        b, s = h.shape[:2]
        masks = {}
        x = _layernorm(h, bp["ln1_g"], bp["ln1_b"])
        masks["w_qkv"] = _quantile_mask(x, quantile)
        qkv = (x.reshape(-1, cfg.hidden) @ bp["w_qkv"] + bp["b_qkv"]) \
            .reshape(b, s, 3 * cfg.hidden)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        attn = _merge_heads(_prefill_attention(
            _split_heads(q, cfg.n_heads), _split_heads(k, cfg.n_heads),
            _split_heads(v, cfg.n_heads), cfg.n_heads))
        masks["w_o"] = _quantile_mask(attn, quantile)
        h_mid = h + (attn.reshape(-1, cfg.hidden) @ bp["w_o"] + bp["b_o"]) \
            .reshape(b, s, cfg.hidden)
        x2 = _layernorm(h_mid, bp["ln2_g"], bp["ln2_b"])
        masks["w_fc"] = _quantile_mask(x2, quantile)
        inner = _gelu(x2.reshape(-1, cfg.hidden) @ bp["w_fc"] + bp["b_fc"])
        masks["w_proj"] = _quantile_mask(inner, quantile)
        masks_per_block.append(masks)
        h, _, _ = block_prefill_fn(cfg, h, *[bp[n] for n in BLOCK_PARAM_NAMES])
    return masks_per_block


# ---------------------------------------------------------------------------
# AOT entry points
# ---------------------------------------------------------------------------

def embed_fn(cfg, ids, embedding, ln_g, ln_b):
    """ids i32[B,S] -> h f32[B,S,H]; BLOOM applies a LN right after the
    word embedding lookup."""
    h = jnp.take(embedding, ids, axis=0)
    return _layernorm(h, ln_g, ln_b)


def _block_core(cfg, h, p, mm):
    """Shared block body; `mm` dispatches f32 vs int8 matmuls.
    Returns (h_out, k_heads, v_heads) with k/v [B,Hh,S,D]."""
    b, s, hd = h.shape
    x = _layernorm(h, p["ln1_g"], p["ln1_b"])
    qkv = mm(x.reshape(-1, hd), p["w_qkv"]).reshape(b, s, 3 * hd) + p["b_qkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q, k, v = (_split_heads(t, cfg.n_heads) for t in (q, k, v))
    attn = _merge_heads(_prefill_attention(q, k, v, cfg.n_heads))
    h = h + mm(attn.reshape(-1, hd), p["w_o"]).reshape(b, s, hd) + p["b_o"]
    x2 = _layernorm(h, p["ln2_g"], p["ln2_b"])
    inner = _gelu(mm(x2.reshape(-1, hd), p["w_fc"]).reshape(b, s, -1) + p["b_fc"])
    h = h + mm(inner.reshape(-1, cfg.ffn), p["w_proj"]).reshape(b, s, hd) + p["b_proj"]
    return h, k, v


def block_prefill_fn(cfg, h, *flat_params):
    """Prefill: h [B,S,H] + 12 params -> (h_out [B,S,H], k, v [B,Hh,S,D])."""
    p = dict(zip(BLOCK_PARAM_NAMES, flat_params))
    return _block_core(cfg, h, p, _mm)


def block_prefill_int8_fn(cfg, h, *flat_params):
    """int8 prefill; params are the int8 packs for matmuls (4 tensors each)
    and plain tensors otherwise — see `flatten_int8_params` for the order."""
    p = unflatten_int8_params(flat_params)
    return _block_core(cfg, h, p, _mm_int8)


def _decode_step(cfg, h, k_cache, v_cache, cache_len, p, mm):
    b, one, hd = h.shape
    x = _layernorm(h, p["ln1_g"], p["ln1_b"])
    qkv = mm(x.reshape(b, hd), p["w_qkv"]).reshape(b, 1, 3 * hd) + p["b_qkv"]
    q, k_new, v_new = jnp.split(qkv, 3, axis=-1)
    d = cfg.head_dim
    q = q.reshape(b, cfg.n_heads, d)
    k_new = k_new.reshape(b, cfg.n_heads, 1, d)
    v_new = v_new.reshape(b, cfg.n_heads, 1, d)
    idx = cache_len[0]
    k_cache = jax.lax.dynamic_update_slice(k_cache, k_new, (0, 0, idx, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_new, (0, 0, idx, 0))
    attn = attn_kernel.decode_attention(q, k_cache, v_cache, idx + 1)
    attn = attn.reshape(b, hd)
    h = h + (mm(attn, p["w_o"]) + p["b_o"]).reshape(b, 1, hd)
    x2 = _layernorm(h, p["ln2_g"], p["ln2_b"])
    inner = _gelu(mm(x2.reshape(b, hd), p["w_fc"]) + p["b_fc"])
    h = h + (mm(inner, p["w_proj"]) + p["b_proj"]).reshape(b, 1, hd)
    return h, k_cache, v_cache


def block_decode_fn(cfg, h, k_cache, v_cache, cache_len, *flat_params):
    """Decode: h [B,1,H], caches [B,Hh,C,D], cache_len i32[1] (# valid
    positions BEFORE this token) -> (h_out, k_cache', v_cache')."""
    p = dict(zip(BLOCK_PARAM_NAMES, flat_params))
    return _decode_step(cfg, h, k_cache, v_cache, cache_len, p, _mm)


def block_decode_int8_fn(cfg, h, k_cache, v_cache, cache_len, *flat_params):
    p = unflatten_int8_params(flat_params)
    return _decode_step(cfg, h, k_cache, v_cache, cache_len, p, _mm_int8)


def _decode_step_ragged(cfg, h, k_cache, v_cache, cache_lens, p, mm):
    """[`_decode_step`] with one cache length PER ROW (`cache_lens`
    i32[B]) — the fused executor call behind ragged continuous batching.
    Row b writes its new K/V at index cache_lens[b] (a bitwise select,
    so untouched cache values pass through exactly) and attends over its
    own cache_lens[b]+1 positions; everything else is the per-row
    arithmetic of the uniform step, so a fused ragged batch reproduces
    each session's solo outputs bit for bit."""
    b, one, hd = h.shape
    x = _layernorm(h, p["ln1_g"], p["ln1_b"])
    qkv = mm(x.reshape(b, hd), p["w_qkv"]).reshape(b, 1, 3 * hd) + p["b_qkv"]
    q, k_new, v_new = jnp.split(qkv, 3, axis=-1)
    d = cfg.head_dim
    q = q.reshape(b, cfg.n_heads, d)
    k_new = k_new.reshape(b, cfg.n_heads, 1, d)
    v_new = v_new.reshape(b, cfg.n_heads, 1, d)
    c = k_cache.shape[2]
    pos = jax.lax.iota(jnp.int32, c)                                   # [C]
    write = pos[None, None, :, None] == cache_lens[:, None, None, None]
    k_cache = jnp.where(write, k_new, k_cache)
    v_cache = jnp.where(write, v_new, v_cache)
    attn = attn_kernel.ragged_decode_attention(q, k_cache, v_cache, cache_lens + 1)
    attn = attn.reshape(b, hd)
    h = h + (mm(attn, p["w_o"]) + p["b_o"]).reshape(b, 1, hd)
    x2 = _layernorm(h, p["ln2_g"], p["ln2_b"])
    inner = _gelu(mm(x2.reshape(b, hd), p["w_fc"]) + p["b_fc"])
    h = h + (mm(inner, p["w_proj"]) + p["b_proj"]).reshape(b, 1, hd)
    return h, k_cache, v_cache


def block_decode_ragged_fn(cfg, h, k_cache, v_cache, cache_lens, *flat_params):
    """Ragged decode: h [B,1,H], caches [B,Hh,C,D], cache_lens i32[B]
    (# valid positions BEFORE this token, per row) -> (h_out, k_cache',
    v_cache')."""
    p = dict(zip(BLOCK_PARAM_NAMES, flat_params))
    return _decode_step_ragged(cfg, h, k_cache, v_cache, cache_lens, p, _mm)


def block_decode_ragged_int8_fn(cfg, h, k_cache, v_cache, cache_lens, *flat_params):
    p = unflatten_int8_params(flat_params)
    return _decode_step_ragged(cfg, h, k_cache, v_cache, cache_lens, p, _mm_int8)


def lm_head_fn(cfg, h, ln_g, ln_b, embedding):
    """h [B,H] -> logits [B,V] (final LN + tied-embedding projection)."""
    x = _layernorm(h, ln_g, ln_b)
    return x @ embedding.T


def block_bwd_fn(cfg, h_in, g_out, *flat_params):
    """Backward through one block for distributed fine-tuning (§2.2):
    servers return grads w.r.t. *activations* only — parameters are
    frozen server-side (clients own the trainable prompts/heads).
    h_in, g_out [B,S,H] -> g_in [B,S,H]."""
    def fwd(h):
        out, _, _ = block_prefill_fn(cfg, h, *flat_params)
        return out
    _, vjp = jax.vjp(fwd, h_in)
    return vjp(g_out)[0]


def quantize_hidden_fn(cfg, h):
    """Comm compression (§3.1): hidden states -> (int8 payload, scales)."""
    return quant_kernel.blockwise_quantize(h)


def dequantize_hidden_fn(cfg, q, scales, shape):
    return quant_kernel.blockwise_dequantize(q, scales, shape)


# ---------------------------------------------------------------------------
# int8 param flattening (fixed order, mirrored in rust/src/model/params.rs)
# ---------------------------------------------------------------------------

def flatten_int8_params(p):
    """dict -> flat tuple: matmuls expand to (w_q, w_scale, w_out, mask)."""
    flat = []
    for name in BLOCK_PARAM_NAMES:
        if name in INT8_MATMULS:
            flat.extend(p[name])
        else:
            flat.append(p[name])
    return tuple(flat)


def unflatten_int8_params(flat):
    p, i = {}, 0
    for name in BLOCK_PARAM_NAMES:
        if name in INT8_MATMULS:
            p[name] = tuple(flat[i:i + 4])
            i += 4
        else:
            p[name] = flat[i]
            i += 1
    return p


# ---------------------------------------------------------------------------
# Whole-model reference (used for golden vectors + python-side tests)
# ---------------------------------------------------------------------------

def forward_full(cfg, params, ids):
    """Full forward: ids [B,S] -> logits [B,S,V] (prefill path per block)."""
    h = embed_fn(cfg, ids, params["embedding"],
                 params["ln_emb_g"], params["ln_emb_b"])
    for bp in params["blocks"]:
        h, _, _ = block_prefill_fn(cfg, h, *[bp[n] for n in BLOCK_PARAM_NAMES])
    x = _layernorm(h, params["ln_f_g"], params["ln_f_b"])
    return x @ params["embedding"].T


def generate_greedy(cfg, params, ids, n_new):
    """Reference greedy generation used to produce golden sequences."""
    b = ids.shape[0]
    c = cfg.max_seq
    caches = [
        (jnp.zeros((b, cfg.n_heads, c, cfg.head_dim), jnp.float32),
         jnp.zeros((b, cfg.n_heads, c, cfg.head_dim), jnp.float32))
        for _ in params["blocks"]]
    h = embed_fn(cfg, ids, params["embedding"],
                 params["ln_emb_g"], params["ln_emb_b"])
    s0 = ids.shape[1]
    for li, bp in enumerate(params["blocks"]):
        flat = [bp[n] for n in BLOCK_PARAM_NAMES]
        h, k, v = block_prefill_fn(cfg, h, *flat)
        kc, vc = caches[li]
        caches[li] = (kc.at[:, :, :s0].set(k), vc.at[:, :, :s0].set(v))
    out = []
    last = h[:, -1]
    for step in range(n_new):
        logits = lm_head_fn(cfg, last, params["ln_f_g"], params["ln_f_b"],
                            params["embedding"])
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(nxt)
        h = embed_fn(cfg, nxt[:, None], params["embedding"],
                     params["ln_emb_g"], params["ln_emb_b"])
        clen = jnp.array([s0 + step], jnp.int32)
        for li, bp in enumerate(params["blocks"]):
            flat = [bp[n] for n in BLOCK_PARAM_NAMES]
            kc, vc = caches[li]
            h, kc, vc = block_decode_fn(cfg, h, kc, vc, clen, *flat)
            caches[li] = (kc, vc)
        last = h[:, 0]
    return jnp.stack(out, axis=1)
