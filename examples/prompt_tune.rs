//! Distributed soft prompt tuning (§2.2, Figure 4) **through the public
//! HTTP API**: the client owns trainable prompts + a classification
//! head; servers run frozen blocks forward AND backward behind
//! `POST /api/v1/forward` / `POST /api/v1/backward` — the raw-activation
//! access that makes the swarm a research platform, not just a text
//! endpoint. Activations ride the binary tensor transport
//! (`application/x-petals-tensor`): bit-identical to JSON, ~5× fewer
//! bytes per training step on the wire.
//!
//! Task: synthetic 2-class sequence classification — class decided by
//! which half of the vocabulary dominates the sequence. Real PJRT
//! compute for every block fwd/bwd; loss curve printed per step.
//!
//! ```sh
//! make artifacts && cargo run --release --example prompt_tune
//! ```

use petals::api::ApiServer;
use petals::config::Rng;
use petals::coordinator::routing::RouteQuery;
use petals::coordinator::session::SessionConfig;
use petals::finetune::{HttpActivations, PromptTuner};
use petals::model::tensor::Tensor;
use petals::model::{ModelHome, Precision, Weights};
use petals::runtime::Runtime;
use petals::server::local::spawn_even_swarm;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn main() -> petals::Result<()> {
    let home = ModelHome::open("artifacts")?;
    let g = home.geometry().clone();
    // fine-tuning entries are exported at batch 4, seq 64
    let (b, s) = (4usize, 64usize);
    println!("== distributed soft prompt tuning over the HTTP API (batch {b}, seq {s}) ==");

    let rt = Arc::new(Runtime::load_filtered(&home, |n| {
        n == format!("embed_b{b}_s{s}")
            || n == format!("block_prefill_b{b}_s{s}")
            || n == format!("block_bwd_b{b}_s{s}")
    })?);

    // servers host frozen blocks (2 servers, f16 — backward needs f16)
    let swarm = Arc::new(spawn_even_swarm(&home, rt.clone(), 2, Precision::F16)?);
    let weights = Weights::load(&home, Precision::F16)?;
    let head = Arc::new(petals::coordinator::client::LocalHead::new(&home, rt.clone(), &weights)?);

    // the public API surface in front of the swarm
    let cfg = SessionConfig {
        n_blocks: g.n_layers,
        max_new: 32,
        route: RouteQuery {
            n_blocks: g.n_layers,
            msg_bytes: (b * s * g.hidden * 4) as u64,
            ..Default::default()
        },
        max_recoveries: 2,
        prefix_tokens: vec![],
    };
    let api = ApiServer::new(swarm, head.clone(), cfg);
    let stop = Arc::new(AtomicBool::new(false));
    let addr = api.serve("127.0.0.1:0", stop.clone())?;
    println!("api server on http://{addr} (forward/backward, binary tensor transport)\n");
    let backend = HttpActivations { addr };

    let n_prompts = 4;
    let n_classes = 2;
    let mut tuner = PromptTuner::new(n_prompts, g.hidden, n_classes, 0.01, 0);

    let mut rng = Rng::new(42);
    let half = (g.vocab / 2) as i32;
    println!("step |  loss  | accuracy");
    let mut final_acc = 0.0;
    for step in 0..30 {
        // synthetic batch: class 0 draws tokens from the low half of the
        // vocab, class 1 from the high half
        let mut ids = vec![0i32; b * s];
        let mut labels = Vec::with_capacity(b);
        for bi in 0..b {
            let cls = bi % 2;
            labels.push(cls);
            for si in n_prompts..s {
                let t = rng.below(half as u64) as i32;
                ids[bi * s + si] = if cls == 0 { t } else { t + half };
            }
        }
        // client-side embedding (prompt slots get overwritten by the
        // trainable prompt vectors inside train_step)
        let embeds = head.embed(&Tensor::from_i32(&[b, s], &ids))?;
        let report = tuner.train_step(&backend, &embeds, &labels, s - 1)?;
        final_acc = report.accuracy;
        println!("{step:4} | {:.4} | {:.2}", report.loss, report.accuracy);
    }
    println!("\nfinal train accuracy: {final_acc:.2}");

    // share the trained module on the hub (§2.3)
    let hub = petals::hub::Hub::open(std::env::temp_dir().join("petals_hub_demo"))?;
    let mut tags = std::collections::BTreeMap::new();
    tags.insert("task".to_string(), "synthetic-cls".to_string());
    tags.insert("base".to_string(), "bloom-mini@1".to_string());
    tags.insert("method".to_string(), "prompt-tuning".to_string());
    let hash = hub.publish("demo/synthetic-cls-prompts", &tuner.export_bytes(), &tags, "30 steps")?;
    println!("published to hub: demo/synthetic-cls-prompts @ {hash}");
    let found = hub.search(&tags);
    println!("hub search by tags found {} module(s)", found.len());
    stop.store(true, Ordering::SeqCst);
    Ok(())
}
