//! Fault tolerance demo (§3.2): kill servers mid-generation and watch
//! sessions recover by re-routing + replaying KV history to replacement
//! servers — with bit-identical output tokens.
//!
//! ```sh
//! make artifacts && cargo run --release --example fault_tolerance
//! ```

use petals::coordinator::client::{LocalHead, Sampler};
use petals::coordinator::routing::RouteQuery;
use petals::coordinator::session::{InferenceSession, PromptShape, SessionConfig};
use petals::model::tensor::Tensor;
use petals::model::{ModelHome, Precision, Weights};
use petals::runtime::Runtime;
use petals::server::local::LocalCluster;
use petals::server::ServerNode;
use std::sync::Arc;

fn main() -> petals::Result<()> {
    let home = ModelHome::open("artifacts")?;
    let g = home.geometry().clone();
    let rt = Arc::new(Runtime::load_filtered(&home, |n| {
        n.contains("_b1_") || n.ends_with("_b1")
    })?);

    // swarm with replicas: each half of the model hosted by 2 servers
    let half = g.n_layers / 2;
    let cluster = LocalCluster::new();
    for (name, span) in [
        ("alpha", 0..half),
        ("alpha-backup", 0..half),
        ("beta", half..g.n_layers),
        ("beta-backup", half..g.n_layers),
    ] {
        cluster.add(ServerNode::start(name, &home, rt.clone(), span, Precision::F16, false)?);
    }

    let weights = Weights::load(&home, Precision::F16)?;
    let head = LocalHead::new(&home, rt, &weights)?;

    let prefix: Vec<i32> = vec![3, 14, 15, 92, 65, 35, 89, 79];
    let n_new = 12;
    let cfg = SessionConfig {
        n_blocks: g.n_layers,
        max_new: n_new,
        route: RouteQuery {
            n_blocks: g.n_layers,
            msg_bytes: (g.hidden * 4) as u64,
            ..Default::default()
        },
        max_recoveries: 5,
        prefix_tokens: vec![],
    };

    // --- reference run, no failures -------------------------------------
    let reference = generate(&cluster, &head, &cfg, &prefix, n_new, 1, &[])?;
    println!("reference tokens: {:?}", reference.0);

    // --- chaos run: kill a different server every 4 steps ----------------
    println!("\nchaos run: killing one in-chain server at steps 3 and 7");
    let chaos = generate(&cluster, &head, &cfg, &prefix, n_new, 2, &[3, 7])?;
    println!("chaos tokens:     {:?}", chaos.0);
    println!("recoveries: {}", chaos.1);

    assert_eq!(reference.0, chaos.0, "tokens must be identical after failover");
    println!("\nOK: {} failovers, output bit-identical — KV replay works", chaos.1);
    Ok(())
}

/// Generate n_new tokens; kill the first hop's current server right
/// before the steps listed in `kill_at`.
fn generate(
    cluster: &LocalCluster,
    head: &LocalHead,
    cfg: &SessionConfig,
    prefix: &[i32],
    n_new: usize,
    session_id: u64,
    kill_at: &[usize],
) -> petals::Result<(Vec<i32>, usize)> {
    // revive everything from previous runs
    for id in cluster.ids() {
        cluster.revive(id);
    }
    // prompt geometry is derived from the prompt, not configured
    let w = head.derive_prefill_width(1, prefix.len())?;
    let shape = PromptShape { batch: 1, prefix_len: prefix.len(), prefill_width: w };
    let mut session = InferenceSession::open(cluster, cfg.clone(), shape, session_id)?;
    let mut ids = vec![0i32; w];
    ids[..prefix.len()].copy_from_slice(prefix);
    let h0 = head.embed(&Tensor::from_i32(&[1, w], &ids))?;
    let h_pre = session.prefill(h0)?;
    let hidden = head.hidden;
    let p = prefix.len();
    let mut last =
        Tensor::from_f32(&[1, hidden], &h_pre.as_f32()[(p - 1) * hidden..p * hidden]);
    let mut tokens = Vec::with_capacity(n_new);
    for step in 0..n_new {
        if kill_at.contains(&step) {
            // kill whichever server currently serves the first hop
            let victim = session.chain()[step % session.chain().len()].server;
            println!("  step {step}: killing {}", victim.short());
            cluster.kill(victim);
        }
        let logits = head.lm_head(&last)?;
        let next = Sampler::Greedy.sample(&logits);
        tokens.push(next[0]);
        let h = head.embed(&Tensor::from_i32(&[1, 1], &next))?;
        let out = session.step(h)?;
        last = Tensor::from_f32(&[1, hidden], out.as_f32());
    }
    let rec = session.recoveries();
    session.close();
    Ok((tokens, rec))
}
