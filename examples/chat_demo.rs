//! Chat application demo (Figure 3) on the v2 streaming API: the HTTP
//! backend serving a swarm, driven by a tiny chat "frontend" that
//! watches tokens arrive one NDJSON event at a time and keeps the
//! conversation's KV server-side across turns.
//!
//! BLOOM-mini's tokenizer is synthetic, so the frontend maps characters
//! to token ids (mod vocab) — the point here is the *backend plumbing*:
//! HTTP -> PETALS client -> swarm sessions -> per-token events, like
//! the paper's backend at https://chat.petals.ml but with streaming and
//! persistent sessions.
//!
//! ```sh
//! make artifacts && cargo run --release --example chat_demo
//! ```

use petals::api::{http_post, http_post_stream, ApiServer, StreamEvent};
use petals::config::json::Value;
use petals::coordinator::client::LocalHead;
use petals::coordinator::routing::RouteQuery;
use petals::coordinator::session::SessionConfig;
use petals::model::{ModelHome, Precision, Weights};
use petals::runtime::Runtime;
use petals::server::local::spawn_even_swarm;
use std::io::Write;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

fn main() -> petals::Result<()> {
    let home = ModelHome::open("artifacts")?;
    let g = home.geometry().clone();
    let rt = Arc::new(Runtime::load_filtered(&home, |n| {
        n.contains("_b1_") || n.ends_with("_b1")
    })?);
    let swarm = Arc::new(spawn_even_swarm(&home, rt.clone(), 2, Precision::F16)?);
    let weights = Weights::load(&home, Precision::F16)?;
    let head = Arc::new(LocalHead::new(&home, rt, &weights)?);

    let cfg = SessionConfig {
        n_blocks: g.n_layers,
        max_new: 32,
        route: RouteQuery {
            n_blocks: g.n_layers,
            msg_bytes: (g.hidden * 4) as u64,
            ..Default::default()
        },
        max_recoveries: 3,
        prefix_tokens: vec![],
    };
    let backend = ApiServer::new(swarm, head, cfg);
    let stop = Arc::new(AtomicBool::new(false));
    let addr = backend.serve("127.0.0.1:0", stop.clone())?;
    println!("api server listening on http://{addr}\n");

    let vocab = g.vocab as i32;
    let tokenize = |text: &str| -> Vec<i32> {
        text.bytes().map(|b| (b as i32) % vocab).collect()
    };

    // --- part 1: watch tokens stream in (POST /api/v1/stream) -----------
    println!("-- streaming: one NDJSON event per token, as produced --");
    let ids = tokenize("Hi! I am choosing a name for my new cat,");
    let body = format!(
        "{{\"inputs\":[{}],\"max_new_tokens\":12,\
         \"sampler\":{{\"kind\":\"top_p\",\"p\":0.9,\"temperature\":0.8,\"seed\":7}}}}",
        ids.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",")
    );
    print!("AI (token ids):");
    http_post_stream(&addr, "/api/v1/stream", &body, |line| {
        match StreamEvent::parse(line) {
            Ok(StreamEvent::Token(t)) => {
                print!(" {}", t.token);
                let _ = std::io::stdout().flush();
            }
            Ok(StreamEvent::Stats(s)) => {
                println!("\n  [{} tokens @ {:.2} steps/s, finish={}]", s.steps, s.steps_per_s, s.finish);
            }
            Ok(StreamEvent::Error { code, message }) => println!("\n  [error {code}: {message}]"),
            Err(_) => {}
        }
    })?;

    // --- part 2: a multi-turn chat on one persistent session ------------
    // the server keeps the conversation's KV between turns, so each turn
    // costs only its own tokens — no re-prefill of the history
    println!("\n-- persistent session: chat turns reuse server-side KV --");
    let open = http_post(
        &addr,
        "/api/v1/session/open",
        &format!(
            "{{\"inputs\":[{}]}}",
            tokenize("You are a helpful cat-naming assistant.")
                .iter()
                .map(|i| i.to_string())
                .collect::<Vec<_>>()
                .join(",")
        ),
    )?;
    let sid = Value::parse(&open)?.get("session")?.u64()?;
    for user_msg in ["what would you recommend?", "something short?"] {
        println!("Human: {user_msg}");
        let ids = tokenize(user_msg);
        let reply = http_post(
            &addr,
            "/api/v1/session/append",
            &format!(
                "{{\"session\":{sid},\"inputs\":[{}],\"max_new_tokens\":10}}",
                ids.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",")
            ),
        )?;
        let v = Value::parse(&reply)?;
        let out: Vec<i64> = v
            .get("outputs")?
            .arr()?
            .iter()
            .map(|x| x.f64().unwrap() as i64)
            .collect();
        println!(
            "AI (token ids @ {:.2} steps/s, cache {} tokens): {out:?}\n",
            v.get("steps_per_s")?.f64()?,
            v.get("cache_len")?.usize()?
        );
    }
    http_post(&addr, "/api/v1/session/close", &format!("{{\"session\":{sid}}}"))?;
    println!("(BLOOM-mini has synthetic weights — token ids stand in for text; the backend/plumbing is the demo)");
    Ok(())
}
