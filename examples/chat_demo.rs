//! Chat application demo (Figure 3): the HTTP backend serving a swarm,
//! driven by a tiny chat "frontend" loop over HTTP.
//!
//! BLOOM-mini's tokenizer is synthetic, so the frontend maps characters
//! to token ids (mod vocab) — the point here is the *backend plumbing*:
//! HTTP -> PETALS client -> swarm sessions -> HTTP reply, like the
//! paper's Flask backend at https://chat.petals.ml.
//!
//! ```sh
//! make artifacts && cargo run --release --example chat_demo
//! ```

use petals::api::{http_post, ChatBackend};
use petals::config::json::Value;
use petals::coordinator::client::LocalHead;
use petals::coordinator::routing::RouteQuery;
use petals::coordinator::session::SessionConfig;
use petals::model::{ModelHome, Precision, Weights};
use petals::runtime::Runtime;
use petals::server::local::spawn_even_swarm;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

fn main() -> petals::Result<()> {
    let home = ModelHome::open("artifacts")?;
    let g = home.geometry().clone();
    let rt = Arc::new(Runtime::load_filtered(&home, |n| {
        n.contains("_b1_") || n.ends_with("_b1")
    })?);
    let swarm = Arc::new(spawn_even_swarm(&home, rt.clone(), 2, Precision::F16)?);
    let weights = Weights::load(&home, Precision::F16)?;
    let head = Arc::new(LocalHead::new(&home, rt, &weights)?);

    let cfg = SessionConfig {
        n_blocks: g.n_layers,
        batch: 1,
        prefill_width: 128,
        prefix_len: 8,
        max_new: 16,
        route: RouteQuery {
            n_blocks: g.n_layers,
            msg_bytes: (g.hidden * 4) as u64,
            ..Default::default()
        },
        max_recoveries: 3,
        prefix_tokens: vec![],
    };
    let backend = ChatBackend::new(swarm, head, cfg);
    let stop = Arc::new(AtomicBool::new(false));
    let addr = backend.serve("127.0.0.1:0", stop.clone())?;
    println!("chat backend listening on http://{addr}\n");

    // --- the "frontend": three chat turns over real HTTP ----------------
    let vocab = g.vocab as i32;
    for user_msg in ["Hi! I am choosing a name for my new cat,", "what would you recommend?", "something short?"] {
        println!("Human: {user_msg}");
        // char-level "tokenizer"
        let ids: Vec<i32> = user_msg.bytes().map(|b| (b as i32) % vocab).collect();
        let body = format!(
            "{{\"inputs\": [{}], \"max_new_tokens\": 12}}",
            ids.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",")
        );
        let reply = http_post(&addr, "/api/v1/generate", &body)?;
        let v = Value::parse(&reply)?;
        let out: Vec<i64> = v
            .get("outputs")?
            .arr()?
            .iter()
            .map(|x| x.f64().unwrap() as i64)
            .collect();
        let rate = v.get("steps_per_s")?.f64()?;
        println!("AI (token ids @ {rate:.2} steps/s): {out:?}\n");
    }
    println!("(BLOOM-mini has synthetic weights — token ids stand in for text; the backend/plumbing is the demo)");
    Ok(())
}
