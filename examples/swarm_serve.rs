//! End-to-end driver (the repo's headline validation): a real TCP swarm
//! serving batched generation requests, reporting latency + throughput.
//!
//! Three server processes (threads here; identical code path to
//! `petals server`) host spans of BLOOM-mini at int8 and f16; a TCP
//! client discovers them by pinging, routes a chain, opens sessions, and
//! serves a stream of generation requests while measuring per-request
//! latency and aggregate steps/s. Results land in EXPERIMENTS.md §E2E.
//!
//! ```sh
//! make artifacts && cargo run --release --example swarm_serve
//! ```

use petals::coordinator::client::{LocalHead, Sampler, SwarmGenerator};
use petals::coordinator::routing::RouteQuery;
use petals::coordinator::session::{ChainClient, SessionConfig};
use petals::metrics::Histogram;
use petals::model::{ModelHome, Precision, Weights};
use petals::runtime::Runtime;
use petals::server::service::{serve, TcpSwarm};
use petals::server::ServerNode;
use std::sync::Arc;

fn main() -> petals::Result<()> {
    let home = ModelHome::open("artifacts")?;
    let g = home.geometry().clone();
    println!("== petals E2E: TCP swarm serving BLOOM-mini ==");
    println!("model: {} layers, hidden {}, vocab {}", g.n_layers, g.hidden, g.vocab);

    println!("compiling entry points (once, off the request path)...");
    let t0 = std::time::Instant::now();
    let rt = Arc::new(Runtime::load_filtered(&home, |n| {
        n.contains("_b1_") || n.ends_with("_b1")
    })?);
    println!("  compiled in {:.1?}", t0.elapsed());

    // three servers over TCP: uneven spans + mixed precision, like a
    // real heterogeneous swarm (int8 server hosts the longest span —
    // that's the point of §3.1)
    let third = g.n_layers / 3;
    let spans = [
        (0..third, Precision::F16),
        (third..2 * third, Precision::F16),
        (2 * third..g.n_layers, Precision::Int8),
    ];
    let mut peers = Vec::new();
    let mut handles = Vec::new();
    for (i, (span, prec)) in spans.into_iter().enumerate() {
        let name = format!("server-{i}");
        let node = ServerNode::start(&name, &home, rt.clone(), span.clone(), prec, true)?;
        let handle = serve(node, "127.0.0.1:0")?;
        println!("  {name}: blocks {span:?} ({prec:?}) @ {}", handle.addr);
        peers.push((name, handle.addr.clone()));
        handles.push(handle);
    }

    // client: local embeddings + LM head, compressed activations on the
    // wire (§3.1), ping-based discovery + beam-search routing (§3.2)
    let weights = Weights::load(&home, Precision::F16)?;
    let head = LocalHead::new(&home, rt, &weights)?;
    let swarm = TcpSwarm::connect(&peers);
    let views = swarm.discover();
    println!("discovered {} servers via ping", views.len());

    let prefix_len = 8;
    let n_new = 16;
    let n_requests = 12;
    let cfg = SessionConfig {
        n_blocks: g.n_layers,
        max_new: n_new,
        route: RouteQuery {
            n_blocks: g.n_layers,
            msg_bytes: (g.hidden + g.hidden / 64 * 4) as u64, // compressed
            ..Default::default()
        },
        max_recoveries: 3,
        prefix_tokens: vec![],
    };

    println!("\nserving {n_requests} generation requests ({n_new} tokens each)...");
    let latency = Histogram::new();
    let mut total_steps = 0usize;
    let mut rng = petals::config::Rng::new(7);
    let run_t0 = std::time::Instant::now();
    for req in 0..n_requests {
        let prefix: Vec<i32> =
            (0..prefix_len).map(|_| rng.below(g.vocab as u64) as i32).collect();
        let generator = SwarmGenerator {
            swarm: &swarm,
            head: &head,
            cfg: cfg.clone(),
            sampler: Sampler::Greedy,
        };
        let out = generator.generate(&[prefix], n_new, 100 + req as u64)?;
        latency.record(out.wall);
        total_steps += out.steps;
        println!(
            "  request {req:2}: {:?}... {:.2} steps/s",
            &out.tokens[0][..4.min(out.tokens[0].len())],
            out.steps as f64 / out.wall.as_secs_f64()
        );
    }
    let wall = run_t0.elapsed();

    println!("\n== results ==");
    println!("requests: {n_requests}, total decode steps: {total_steps}");
    println!("wall: {wall:.2?} -> {:.2} steps/s aggregate", total_steps as f64 / wall.as_secs_f64());
    println!("request latency: {}", latency.summary());
    for h in &handles {
        println!("  {} served: {}", h.node.id.short(), h.node.metrics.report());
        h.shutdown();
    }
    Ok(())
}
