//! Quickstart: generate text over an in-process Petals swarm.
//!
//! The Rust rendition of the paper's Figure 2 snippet: the client embeds
//! tokens locally, streams hidden states through a chain of servers that
//! each host a span of Transformer blocks, and samples next tokens from
//! the locally-computed logits.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use petals::coordinator::client::{LocalHead, Sampler, SwarmGenerator};
use petals::coordinator::routing::RouteQuery;
use petals::coordinator::session::SessionConfig;
use petals::model::{ModelHome, Precision, Weights};
use petals::runtime::Runtime;
use petals::server::local::spawn_even_swarm;
use std::sync::Arc;

fn main() -> petals::Result<()> {
    // 1. open the AOT artifacts (built once by `make artifacts`)
    let home = ModelHome::open("artifacts")?;
    let g = home.geometry().clone();
    println!("model: {} layers, hidden {}, vocab {}", g.n_layers, g.hidden, g.vocab);

    // 2. compile the batch-1 entry points once
    let rt = Arc::new(Runtime::load_filtered(&home, |n| {
        n.contains("_b1_") || n.ends_with("_b1")
    })?);

    // 3. spawn a local swarm: 2 servers, each hosting half the blocks
    let swarm = spawn_even_swarm(&home, rt.clone(), 2, Precision::F16)?;
    println!("swarm: {} servers", swarm.ids().len());

    // 4. the client keeps embeddings + LM head local (§2.1)
    let weights = Weights::load(&home, Precision::F16)?;
    let head = LocalHead::new(&home, rt, &weights)?;

    // 5. an inference session: chain formation, KV caches, recovery are
    //    transparent (Figure 2's `model.inference_session()`)
    let prefix: Vec<i32> = vec![11, 22, 33, 44, 55, 66, 77, 88];
    let cfg = SessionConfig {
        n_blocks: g.n_layers,
        max_new: 32,
        route: RouteQuery {
            n_blocks: g.n_layers,
            msg_bytes: (g.hidden * 4) as u64,
            ..Default::default()
        },
        max_recoveries: 3,
        prefix_tokens: vec![],
    };
    let generator = SwarmGenerator {
        swarm: &swarm,
        head: &head,
        cfg,
        sampler: Sampler::Greedy,
    };
    let out = generator.generate(&[prefix.clone()], 16, 1)?;

    println!("prefix:    {prefix:?}");
    println!("generated: {:?}", out.tokens[0]);
    println!(
        "{} steps in {:.2?} = {:.2} steps/s",
        out.steps,
        out.wall,
        out.steps as f64 / out.wall.as_secs_f64()
    );
    Ok(())
}
